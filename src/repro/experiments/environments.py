"""Environments: one propose/observe world per evaluation track.

The paper evaluates placement strategies against two different oracles —
the analytical TPD cost model (Fig. 3) and the measured round delay of a
real federated run (Fig. 4). Both are the same *protocol* here: an
:class:`Environment` answers ``step(round_idx, placement) ->
RoundObservation`` and a :class:`~repro.core.placement.PlacementStrategy`
is driven through the identical loop in both worlds:

    env.begin()
    for r in range(rounds):
        p = strategy.propose(r)
        obs = env.step(r, p)
        strategy.observe(p, obs.tpd)

``SimulatedEnvironment`` wraps :class:`repro.core.cost_model.CostModel`
(or the two-tier pod variant); ``EmulatedEnvironment`` wraps
:class:`repro.fl.orchestrator.FederatedOrchestrator` and reuses its
``run_round`` step, so observations are bit-identical to
``FederatedOrchestrator.run``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CostModel, TwoTierCostModel
from repro.core.hierarchy import ClientPool, Hierarchy, TopologyUpdate, slot_remap
from repro.fl.distributed import elastic_rehierarchize
from repro.online import (
    AggregatorBuffer,
    ArrivalProcess,
    AsyncConfig,
    BufferDeadline,
    BufferedPart,
    BufferEntry,
    PartialArrival,
    RootComplete,
    UpdateArrival,
    VirtualClock,
    async_merge_batched,
    flush_count,
)


@dataclass
class RoundObservation:
    """What one environment step hands back to the runner/strategy."""
    round_idx: int
    placement: np.ndarray
    tpd: float                              # the black-box signal
    metrics: Dict[str, float] = field(default_factory=dict)
    topology_version: int = 0               # elastic re-hierarchizations
    log: List[str] = field(default_factory=list)  # env trace (online)


@runtime_checkable
class Environment(Protocol):
    """The propose/observe world every strategy runs against."""
    kind: str
    hierarchy: Hierarchy
    clients: ClientPool

    def begin(self) -> None:
        """One-time setup (compile/warmup) before round 0."""
        ...

    def step(self, round_idx: int, placement) -> RoundObservation:
        """Execute/evaluate one round at ``placement``."""
        ...

    def sync_topology(self) -> Optional[TopologyUpdate]:
        """Reconcile the topology with the (possibly resized) client
        pool; returns the update strategies must migrate through, or
        ``None`` when nothing changed."""
        ...


class SimulatedEnvironment:
    """The Fig. 3 world: rounds cost what eqs. 6-7 say they cost.

    Exposes ``cost_model`` (scalar + swarm-vectorized evaluators) so
    swarm-mode drivers (``FlagSwapPSO.run`` with ``batch_fitness_fn``)
    ride the same object the step loop uses. The cost model reads the
    pool by reference — event schedules that mutate ``clients`` in place
    are reflected in the very next ``step``.

    The topology is ELASTIC: the hierarchy is a versioned run property,
    not a construction-time constant. After ``ClientJoin``/``ClientLeave``
    events resize the pool, :meth:`sync_topology` re-hierarchizes (via
    ``choose_fl_hierarchy``) whenever the population leaves the current
    tree's capacity window ``[min_clients, max_clients]``, bumps
    ``topology_version``, and retargets the cost model in place — the
    returned :class:`TopologyUpdate` carries the slot/client remaps the
    strategies' ``migrate`` hooks consume.
    """
    kind = "simulated"

    def __init__(self, hierarchy: Hierarchy, clients: ClientPool,
                 cost_model: Optional[CostModel] = None):
        self.hierarchy = hierarchy
        self.clients = clients
        self.cost_model = cost_model if cost_model is not None \
            else CostModel(hierarchy, clients)
        self.topology_version = 0
        # scenarios may start deliberately overstuffed (large-10k packs
        # ~7 trainers/leaf): the grow threshold honors the construction-
        # time population so a stray join doesn't snap the tree
        self._capacity = max(hierarchy.max_clients, len(clients))

    def begin(self) -> None:
        pass

    def sync_topology(self) -> Optional[TopologyUpdate]:
        """Reconcile hierarchy with the pool after this round's events.

        Drains the pool's resize log (composing the old->new client id
        remap). Any resize yields a new hierarchy — at minimum the
        client count changed — and the STRUCTURE is rebuilt through
        ``choose_fl_hierarchy`` when the population crossed the capacity
        window; within the window only ``n_clients`` is re-pinned (same
        tree, cheaper migration). Deterministic: no rng is consumed, so
        sequential and batched sweeps see identical updates.
        """
        drained = self.clients.drain_resizes()
        if drained is None:
            return None
        old_n, client_remap = drained
        old_h = self.hierarchy
        if old_n != old_h.total_clients:
            raise RuntimeError(
                f"pool resize log starts at {old_n} clients but the "
                f"hierarchy tracked {old_h.total_clients}")
        n = len(self.clients)
        # the shared capacity-window rule (fl.distributed): in-window
        # resizes keep the tree and re-pin the client count, crossings
        # rebuild the structure — identical on the emulated track
        new_h, self._capacity = elastic_rehierarchize(old_h, n,
                                                      self._capacity)
        self.topology_version += 1
        update = TopologyUpdate(
            version=self.topology_version,
            old_hierarchy=old_h, new_hierarchy=new_h,
            slot_remap=slot_remap(old_h, new_h),
            client_remap=client_remap)
        self.hierarchy = new_h
        self.cost_model.retarget(new_h)
        return update

    def step(self, round_idx: int, placement) -> RoundObservation:
        # single-placement fast path: the cached exact (float64 numpy)
        # vectorized evaluator — bit-identical to CostModel.tpd (pinned
        # by the parity suite), but the O(C) Python trainer/cluster
        # loops never run, which is what makes 1k-10k client scenarios
        # steppable at all
        placement = np.asarray(placement, np.int64)
        self.hierarchy.validate_placement(placement)
        tpd = self.cost_model.tpd_fast(placement)
        return RoundObservation(round_idx=round_idx, placement=placement,
                                tpd=tpd,
                                topology_version=self.topology_version)


class EmulatedEnvironment:
    """The Fig. 4 world: rounds cost what the federated run measures.

    Thin adapter over ``FederatedOrchestrator`` — ``step`` IS
    ``orchestrator.run_round``, so a strategy driven through this
    environment reproduces ``FederatedOrchestrator.run`` exactly
    (including model state evolution and eval metrics).

    The topology is ELASTIC, exactly like the simulated track:
    ``ClientJoin``/``ClientLeave`` events resize the orchestrator's live
    pool, and :meth:`sync_topology` delegates to
    ``FederatedOrchestrator.sync_population`` — survivors keep their
    model weights (the global model) and data shards, joiners are
    provisioned shards and train from the current global params, and the
    re-hierarchization rule is the SAME capacity-window logic, so one
    event schedule replays the identical hierarchy/``topology_version``
    sequence on both tracks.
    """
    kind = "emulated"

    def __init__(self, orchestrator):
        self.orchestrator = orchestrator
        self.clients = orchestrator.clients
        self._cost_model: Optional[CostModel] = None

    @property
    def hierarchy(self) -> Hierarchy:
        """The orchestrator's CURRENT hierarchy (elastic runs rebind it
        mid-flight, so this must never be snapshotted at construction)."""
        return self.orchestrator.hierarchy

    @property
    def topology_version(self) -> int:
        return self.orchestrator.topology_version

    @property
    def cost_model(self) -> CostModel:
        """Analytic eqs. 6-7 view of the same pool (lazily built) — only
        used as strategy-construction context (e.g. the exhaustive
        oracle); the observed TPD always comes from the orchestrator."""
        if self._cost_model is None:
            self._cost_model = CostModel(self.hierarchy, self.clients)
        return self._cost_model

    def begin(self) -> None:
        self.orchestrator.warmup()

    def sync_topology(self) -> Optional[TopologyUpdate]:
        """Reconcile the orchestrator with this round's pool resizes:
        data shards carried/provisioned, FedAvg weights recomputed, the
        round engine retargeted, and the returned update's
        slot/client remaps feed the strategies' ``migrate`` hooks (the
        runner calls them) — an aggregator-host departure is repaired
        before the next proposal."""
        update = self.orchestrator.sync_population()
        if update is not None and self._cost_model is not None:
            # keep the analytic view strategies hold a reference to
            # pointed at the live topology
            self._cost_model.retarget(update.new_hierarchy)
        return update

    def step(self, round_idx: int, placement) -> RoundObservation:
        rec = self.orchestrator.run_round(round_idx, placement)
        return RoundObservation(
            round_idx=round_idx,
            placement=np.asarray(rec.placement, np.int64),
            tpd=float(rec.tpd),
            metrics={"loss": rec.loss, "accuracy": rec.accuracy,
                     "train_time": rec.train_time,
                     "agg_time": rec.agg_time},
            topology_version=self.topology_version)


class OnlineEnvironment:
    """The asynchronous world: a discrete-event queue over the live
    ``FederatedOrchestrator``.

    Each ``step`` dispatches every *idle* client's local training from
    the current global model and schedules one ``UpdateArrival`` per
    client at ``now + train_delay * jitter`` on the virtual clock
    (:class:`~repro.online.clock.VirtualClock`; seeded per-client
    jitter, no wall-clock anywhere). Arrivals route to the client's
    aggregator slot under the CURRENT placement, where count-or-deadline
    :class:`~repro.online.async_fedavg.AggregatorBuffer`\\ s flush
    partials up the tree, each flush charging the same eq. 6 cluster
    delay the synchronous engines charge. The round concludes at the
    first ROOT flush: its entries merge into the global model via
    staleness-weighted async FedAvg
    (:func:`~repro.online.async_fedavg.async_merge_batched`), and the
    observed TPD is the virtual time from dispatch to merge. Clients
    still in flight simply stay in flight — rounds OVERLAP, and their
    updates land with positive staleness.

    Two extra mechanisms:

    * **Degenerate lockstep** — a config with zero jitter, full-cohort
      flushes and no deadline (``AsyncConfig.degenerate``) routes the
      model transition through the orchestrator's own
      ``train_cohort``/``aggregate_cohort`` executables, making the run
      bit-identical to ``EmulatedEnvironment`` (the parity pin).
    * **Delay-triggered re-optimization** — per-slot EWMAs track
      observed flush latency; a flush exceeding ``reopt_threshold`` x
      its slot's EWMA swaps that slot's host for the
      fastest-by-observed-delay unplaced client MID-ROUND (placement
      changes off the round boundary), and the next ``sync_topology``
      surfaces an identity :class:`TopologyUpdate` pulse through the
      elastic machinery so strategies' ``migrate`` hooks see the epoch.

    The elastic track composes: pool resizes flow through
    ``sync_population`` exactly as in ``EmulatedEnvironment``, with
    in-flight updates re-keyed across the id remap (departed clients'
    updates are dropped; survivors' stay in transit).
    """
    kind = "online"

    def __init__(self, orchestrator, config: Optional[AsyncConfig] = None,
                 seed: int = 0):
        if orchestrator.engine != "batched":
            raise ValueError("OnlineEnvironment needs the batched round "
                             f"engine, got {orchestrator.engine!r}")
        self.orchestrator = orchestrator
        self.clients = orchestrator.clients
        self.cfg = config if config is not None else AsyncConfig()
        self.clock = VirtualClock()
        self._arrival = ArrivalProcess(seed, self.cfg.jitter)
        self._cost_model: Optional[CostModel] = None

        # routing + buffers are (re)built lazily from the placement each
        # step; see _set_placement
        self._placement: Optional[np.ndarray] = None
        self._client_slot: Optional[np.ndarray] = None
        self._buffers: List[AggregatorBuffer] = []

        # in-flight bookkeeping
        self._in_flight: set = set()          # clients with a pending arrival
        self._sent: Dict[tuple, float] = {}   # (client, version) -> t_dispatch
        self._store: Dict[tuple, object] = {}  # (client, version) -> update
        self._round = 0
        self._merge_stats: Optional[Dict[str, float]] = None

        # observed-delay state driving the re-optimization trigger
        self._slot_ewma: Optional[np.ndarray] = None
        self._slot_obs: Optional[np.ndarray] = None
        self._client_delay: Dict[int, float] = {}
        self._reopt_swaps = 0

        self._trace: List[str] = []
        self._pending_pulse = False
        self._topology_version = 0

    # -- protocol surface --------------------------------------------------
    @property
    def hierarchy(self) -> Hierarchy:
        return self.orchestrator.hierarchy

    @property
    def topology_version(self) -> int:
        return self._topology_version

    @property
    def cost_model(self) -> CostModel:
        """Analytic construction-time context for strategies (exhaustive
        oracle etc.) — observed TPD always comes from the event queue."""
        if self._cost_model is None:
            self._cost_model = CostModel(self.hierarchy, self.clients)
        return self._cost_model

    def begin(self) -> None:
        self.orchestrator.warmup()

    # -- placement routing -------------------------------------------------
    def _set_placement(self, placement: np.ndarray) -> None:
        """Adopt ``placement``: rebuild the client->slot routing table,
        per-slot expected-part counts and buffer thresholds. Buffered
        parts survive a placement change in place (they are in transit
        at their old slot); a topology change (different D) rebuilds the
        buffers from scratch — migration already re-injected their
        entries as arrivals."""
        h = self.hierarchy
        if (self._placement is not None
                and len(self._buffers) == h.dimensions
                and np.array_equal(self._placement, placement)):
            return
        self._placement = placement.copy()
        C = h.total_clients
        trainers = h.trainer_assignment(self._placement)
        leaf_start = h.level_starts[h.depth - 1]
        cs = np.full(C, -1, np.int64)
        for li, t_list in enumerate(trainers):
            for c in t_list:
                cs[c] = leaf_start + li
        for s in range(h.dimensions):
            cs[int(self._placement[s])] = s
        self._client_slot = cs

        rebuilt = len(self._buffers) != h.dimensions
        new_buffers: List[AggregatorBuffer] = []
        for s in range(h.dimensions):
            kids = h.children_slots(s)
            expected = (len(kids) if kids
                        else len(trainers[s - leaf_start])) + 1
            threshold = flush_count(expected, self.cfg.flush_fraction)
            if rebuilt:
                new_buffers.append(AggregatorBuffer(
                    slot=s, expected=expected, threshold=threshold))
            else:
                self._buffers[s].expected = expected
                self._buffers[s].threshold = threshold
        if rebuilt:
            self._buffers = new_buffers
            self._slot_ewma = np.zeros(h.dimensions, np.float64)
            self._slot_obs = np.zeros(h.dimensions, np.int64)

    # -- elastic topology --------------------------------------------------
    def sync_topology(self) -> Optional[TopologyUpdate]:
        """Pool resizes reconcile through ``sync_population`` (same
        elastic machinery as the emulated track) with the event engine
        migrated across the id remap; additionally, a mid-round
        re-optimization swap raises a PULSE — an identity update with a
        bumped version — so strategies' ``migrate`` hooks observe the
        new placement epoch even though no client ids moved."""
        update = self.orchestrator.sync_population()
        if update is not None:
            if self._cost_model is not None:
                self._cost_model.retarget(update.new_hierarchy)
            self._migrate_engine(update)
            self._pending_pulse = False
            self._topology_version += 1
            return dataclasses.replace(update,
                                       version=self._topology_version)
        if self._pending_pulse:
            self._pending_pulse = False
            self._topology_version += 1
            h = self.hierarchy
            return TopologyUpdate(
                version=self._topology_version,
                old_hierarchy=h, new_hierarchy=h,
                slot_remap=slot_remap(h, h), client_remap=None)
        return None

    def _migrate_engine(self, update: TopologyUpdate) -> None:
        """Re-key every client-id-indexed piece of event state across a
        pool renumbering; in-flight and buffered updates of departed
        clients are dropped, survivors' are conservatively re-injected
        as arrivals at their original virtual times (buffered ones at
        ``now``) so they re-route under the NEW topology."""
        remap = update.client_remap

        def alive(c: int) -> int:
            if remap is None:
                return c
            return int(remap[c]) if c < len(remap) and remap[c] >= 0 else -1

        self._arrival.migrate(remap)
        self._client_delay = {
            alive(c): v for c, v in sorted(self._client_delay.items())
            if alive(c) >= 0}
        self._in_flight = {alive(c) for c in self._in_flight
                           if alive(c) >= 0}
        self._sent = {(alive(c), v): t
                      for (c, v), t in sorted(self._sent.items())
                      if alive(c) >= 0}
        self._store = {
            (alive(c), v): u
            for (c, v), u in sorted(self._store.items(),
                                    key=lambda kv: kv[0])
            if alive(c) >= 0}

        pend = self.clock.pending()
        self.clock.replace([])
        for t, _seq, ev in pend:
            if isinstance(ev, UpdateArrival):
                nc = alive(ev.client)
                if nc >= 0:
                    self.clock.schedule(t, UpdateArrival(nc, ev.version))
            elif isinstance(ev, (PartialArrival, RootComplete)):
                for e in ev.entries:
                    nc = alive(e.client)
                    if nc >= 0:
                        self.clock.schedule(
                            t, UpdateArrival(nc, e.version))
            # BufferDeadline: dropped — the buffers rebuild empty
        for buf in self._buffers:
            for part in buf.take():
                for e in part.entries:
                    nc = alive(e.client)
                    if nc >= 0:
                        self.clock.schedule(
                            self.clock.now, UpdateArrival(nc, e.version))

        # force a full routing/buffer rebuild at the next step (the
        # strategy proposes a placement for the NEW hierarchy then)
        self._placement = None
        self._buffers = []

    # -- the step ----------------------------------------------------------
    def step(self, round_idx: int, placement) -> RoundObservation:
        orch = self.orchestrator
        placement = np.asarray(placement, np.int64)
        self.hierarchy.validate_placement(placement)
        self._set_placement(placement)
        self._round = round_idx
        t_r = self.clock.now

        C = self.hierarchy.total_clients
        cohort = np.asarray([c for c in range(C)
                             if c not in self._in_flight], np.int64)
        overlap = 1.0 - cohort.size / C
        stacked, train_times = orch.train_cohort(cohort, round_idx)
        if cohort.size:
            for j, c in enumerate(cohort):
                c = int(c)
                key = (c, round_idx)
                self._sent[key] = t_r
                if not self.cfg.degenerate:
                    self._store[key] = jax.tree.map(
                        lambda x, j=j: x[j], stacked)
                delay = float(train_times[j]) * self._arrival.factor(c)
                self.clock.schedule(t_r + delay,
                                    UpdateArrival(c, round_idx))
                self._in_flight.add(c)
            self._trace.append(
                f"t={t_r:.4f} r{round_idx}: dispatched {cohort.size}/{C} "
                f"clients ({len(self._in_flight)} now in flight)")

        if self.cfg.degenerate:
            tpd, extra = self._step_degenerate(round_idx, placement,
                                               cohort, stacked,
                                               train_times, t_r)
        else:
            tpd, extra = self._step_async(round_idx, t_r)

        loss, acc = orch.evaluate_global()
        metrics = {"loss": loss, "accuracy": acc, "overlap": overlap,
                   "reopt_swaps": float(self._reopt_swaps), **extra}
        log, self._trace = self._trace, []
        return RoundObservation(
            round_idx=round_idx, placement=self._placement.copy(),
            tpd=tpd, metrics=metrics,
            topology_version=self._topology_version, log=log)

    # -- degenerate lockstep path -------------------------------------------
    def _step_degenerate(self, r: int, placement, cohort, stacked,
                         train_times, t_r: float):
        """Zero jitter + full-cohort flush + no deadline: the round IS
        synchronous. The model transition runs through the orchestrator's
        own executables (``train_cohort`` full-cohort fast path +
        ``aggregate_cohort``), so tpd/loss/accuracy match
        ``EmulatedEnvironment.step`` bit for bit — while the arrival
        events still stream through the virtual clock, keeping the
        trace real."""
        orch = self.orchestrator
        if cohort.size != self.hierarchy.total_clients:
            raise RuntimeError("degenerate online round with clients in "
                               "flight — the lockstep invariant broke")
        while self.clock:
            t, ev = self.clock.pop()
            self._in_flight.discard(ev.client)
            sent = self._sent.pop((ev.client, ev.version), None)
            if sent is not None:
                self._observe_delay(ev.client, t - sent)
        train_time = float(np.max(train_times))
        new_params, agg_time = orch.aggregate_cohort(stacked, placement)
        orch.set_global(new_params)
        t_done = t_r + train_time + agg_time
        self.clock.advance_to(t_done)
        self._trace.append(
            f"t={t_done:.4f} r{r}: lockstep merge of {cohort.size} "
            f"updates (train={train_time:.4f} agg={agg_time:.4f})")
        tpd = (train_time + agg_time) * orch.time_scale
        extra = {"train_time": train_time, "agg_time": agg_time,
                 "merged": float(cohort.size),
                 "staleness_mean": 0.0, "staleness_max": 0.0}
        return tpd, extra

    # -- event-driven async path ---------------------------------------------
    def _step_async(self, r: int, t_r: float):
        """Drive the event queue until the first root merge; the TPD is
        the virtual dispatch->merge latency."""
        h = self.hierarchy
        self._merge_stats = None
        forced = 0
        force_limit = h.total_clients * h.depth + h.dimensions + 8
        while self._merge_stats is None:
            if not self.clock:
                slot = self._deepest_nonempty_slot()
                if slot is None:
                    # nothing in flight at all: the model is unchanged
                    self._merge_stats = {"merged": 0.0,
                                         "staleness_mean": 0.0,
                                         "staleness_max": 0.0}
                    break
                forced += 1
                if forced > force_limit:
                    raise RuntimeError("online event loop stalled "
                                       "(forced-flush runaway)")
                self._flush(slot, self.clock.now, why="drain")
                continue
            t, ev = self.clock.pop()
            if isinstance(ev, UpdateArrival):
                self._on_arrival(t, ev)
            elif isinstance(ev, PartialArrival):
                self._deposit(ev.slot,
                              BufferedPart(src=ev.src, entries=ev.entries),
                              t)
            elif isinstance(ev, BufferDeadline):
                buf = self._buffers[ev.slot]
                if buf.epoch == ev.epoch and not buf.empty:
                    self._flush(ev.slot, t, why="deadline")
            elif isinstance(ev, RootComplete):
                self._merge(t, ev.entries, r)
            else:
                raise TypeError(f"unknown online event {ev!r}")
        tpd = (self.clock.now - t_r) * self.orchestrator.time_scale
        return tpd, dict(self._merge_stats)

    def _on_arrival(self, t: float, ev: UpdateArrival) -> None:
        self._in_flight.discard(ev.client)
        sent = self._sent.pop((ev.client, ev.version), None)
        if sent is not None:
            self._observe_delay(ev.client, t - sent)
        slot = int(self._client_slot[ev.client])
        self._deposit(slot, BufferedPart(
            src=ev.client,
            entries=(BufferEntry(ev.client, ev.version),)), t)

    def _deposit(self, slot: int, part: BufferedPart, t: float) -> None:
        buf = self._buffers[slot]
        was_empty = buf.empty
        if buf.deposit(part):
            self._flush(slot, t, why="count")
        elif was_empty and self.cfg.flush_timeout > 0:
            self.clock.schedule(t + self.cfg.flush_timeout,
                                BufferDeadline(slot, buf.epoch))

    def _flush(self, slot: int, t: float, why: str) -> None:
        """Drain one buffer: charge the eq. 6 cluster delay for the
        actual payloads, feed the latency EWMA (possibly triggering a
        host swap), and forward the merged entry set up the tree."""
        h = self.hierarchy
        parts = self._buffers[slot].take()
        host = int(self._placement[slot])
        members = [p.src for p in parts]
        ct = self.orchestrator.cluster_delay(host, members, len(parts))
        self._note_flush_latency(slot, ct, t)
        entries = tuple(e for p in parts for e in p.entries)
        self._trace.append(
            f"t={t:.4f} flush[{why}] slot {slot} host c{host} "
            f"parts={len(parts)} updates={len(entries)} dt={ct:.4f}")
        t_out = t + ct
        if slot == 0:
            self.clock.schedule(t_out, RootComplete(entries))
        else:
            self.clock.schedule(t_out, PartialArrival(
                slot=h.parent_slot(slot), src=host, entries=entries))

    def _merge(self, t: float, entries, r: int) -> None:
        """The root flush landed: staleness-weighted merge into the
        global model; the round concludes here."""
        orch = self.orchestrator
        order = sorted(entries, key=lambda e: (e.version, e.client))
        clients = np.asarray([e.client for e in order], np.int64)
        versions = np.asarray([e.version for e in order], np.int64)
        staleness = (r - versions).astype(np.float64)
        base_w = orch.weights[clients]
        trees = [self._store.pop((e.client, e.version)) for e in order]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        new_global = async_merge_batched(
            orch.params, stacked, base_w, staleness,
            self.cfg.staleness_alpha, self.cfg.server_lr)
        orch.set_global(new_global)
        self._trace.append(
            f"t={t:.4f} r{r}: root merge of {len(order)} updates "
            f"(staleness mean {staleness.mean():.2f} "
            f"max {staleness.max():.0f})")
        self._merge_stats = {
            "merged": float(len(order)),
            "staleness_mean": float(staleness.mean()),
            "staleness_max": float(staleness.max())}

    # -- observed-delay EWMAs + the re-optimization trigger ------------------
    def _observe_delay(self, client: int, delay: float) -> None:
        b = self.cfg.reopt_beta
        prev = self._client_delay.get(client)
        self._client_delay[client] = delay if prev is None \
            else b * prev + (1.0 - b) * delay

    def _note_flush_latency(self, slot: int, ct: float, t: float) -> None:
        cfg = self.cfg
        prior = float(self._slot_ewma[slot])
        obs = int(self._slot_obs[slot])
        if (cfg.reopt_threshold > 0 and obs >= 2
                and ct > cfg.reopt_threshold * prior
                and self._swap_host(slot, ct, prior, t)):
            # the slot's latency history belonged to the old host
            self._slot_ewma[slot] = 0.0
            self._slot_obs[slot] = 0
            return
        b = cfg.reopt_beta
        self._slot_ewma[slot] = ct if obs == 0 \
            else b * prior + (1.0 - b) * ct
        self._slot_obs[slot] = obs + 1

    def _swap_host(self, slot: int, ct: float, ewma: float,
                   t: float) -> bool:
        """Delay-triggered mid-round re-optimization: replace the slot's
        host with the fastest unplaced client by OBSERVED train-delay
        EWMA (the environment only ever acts on observed signals — the
        pool's pspeed stays black-box). Takes effect immediately: the
        very next flush of this slot charges the new host."""
        placed = {int(c) for c in self._placement}
        old = int(self._placement[slot])
        best, best_delay = -1, np.inf
        for c in range(self.hierarchy.total_clients):
            if c in placed:
                continue
            d = self._client_delay.get(c)
            if d is not None and d < best_delay:
                best, best_delay = c, d
        old_delay = self._client_delay.get(old)
        if best < 0 or (old_delay is not None and best_delay >= old_delay):
            return False
        placement = self._placement.copy()
        placement[slot] = best
        self._set_placement(placement)
        self._reopt_swaps += 1
        self._pending_pulse = True
        self._trace.append(
            f"t={t:.4f} REOPT slot {slot}: host c{old} -> c{best} "
            f"(flush {ct:.4f} > {self.cfg.reopt_threshold:g}x "
            f"ewma {ewma:.4f})")
        return True

    def _deepest_nonempty_slot(self) -> Optional[int]:
        for s in range(self.hierarchy.dimensions - 1, -1, -1):
            if not self._buffers[s].empty:
                return s
        return None


def build_environment(spec, seed: int = 0) -> Environment:
    """Materialize a ScenarioSpec into a fresh environment for one run."""
    hierarchy = spec.make_hierarchy()
    pool = spec.make_pool(seed)
    if spec.kind == "simulated":
        if spec.pods:
            n = hierarchy.total_clients
            pod_of = np.arange(n) * spec.pods // n
            cm = TwoTierCostModel(hierarchy, pool,
                                  memory_penalty=spec.memory_penalty,
                                  pod_of=pod_of, ici_cost=spec.ici_cost,
                                  dcn_cost=spec.dcn_cost)
        else:
            cm = CostModel(hierarchy, pool,
                           memory_penalty=spec.memory_penalty)
        return SimulatedEnvironment(hierarchy, pool, cm)

    # emulated/online: build model + data + orchestrator
    from repro.configs import get_config
    from repro.data.synthetic import make_federated_dataset
    from repro.fl.orchestrator import FederatedOrchestrator
    from repro.models import get_model

    cfg = get_config(spec.model)
    model = get_model(cfg)
    data = make_federated_dataset(cfg, hierarchy.total_clients, seed=seed)
    orch = FederatedOrchestrator(
        model, hierarchy, pool, data,
        local_steps=spec.local_steps, batch_size=spec.batch_size,
        seed=seed, comm_latency=spec.comm_latency, timing=spec.timing,
        engine=spec.engine)
    if spec.kind == "online":
        async_cfg = AsyncConfig(
            jitter=spec.jitter, staleness_alpha=spec.staleness_alpha,
            flush_fraction=spec.flush_fraction,
            flush_timeout=spec.flush_timeout, server_lr=spec.server_lr,
            reopt_threshold=spec.reopt_threshold,
            reopt_beta=spec.reopt_beta)
        return OnlineEnvironment(orch, async_cfg, seed=seed)
    return EmulatedEnvironment(orch)
