"""Environments: one propose/observe world per evaluation track.

The paper evaluates placement strategies against two different oracles —
the analytical TPD cost model (Fig. 3) and the measured round delay of a
real federated run (Fig. 4). Both are the same *protocol* here: an
:class:`Environment` answers ``step(round_idx, placement) ->
RoundObservation`` and a :class:`~repro.core.placement.PlacementStrategy`
is driven through the identical loop in both worlds:

    env.begin()
    for r in range(rounds):
        p = strategy.propose(r)
        obs = env.step(r, p)
        strategy.observe(p, obs.tpd)

``SimulatedEnvironment`` wraps :class:`repro.core.cost_model.CostModel`
(or the two-tier pod variant); ``EmulatedEnvironment`` wraps
:class:`repro.fl.orchestrator.FederatedOrchestrator` and reuses its
``run_round`` step, so observations are bit-identical to
``FederatedOrchestrator.run``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.cost_model import CostModel, TwoTierCostModel
from repro.core.hierarchy import ClientPool, Hierarchy, TopologyUpdate, slot_remap
from repro.fl.distributed import elastic_rehierarchize


@dataclass
class RoundObservation:
    """What one environment step hands back to the runner/strategy."""
    round_idx: int
    placement: np.ndarray
    tpd: float                              # the black-box signal
    metrics: Dict[str, float] = field(default_factory=dict)
    topology_version: int = 0               # elastic re-hierarchizations


@runtime_checkable
class Environment(Protocol):
    """The propose/observe world every strategy runs against."""
    kind: str
    hierarchy: Hierarchy
    clients: ClientPool

    def begin(self) -> None:
        """One-time setup (compile/warmup) before round 0."""
        ...

    def step(self, round_idx: int, placement) -> RoundObservation:
        """Execute/evaluate one round at ``placement``."""
        ...

    def sync_topology(self) -> Optional[TopologyUpdate]:
        """Reconcile the topology with the (possibly resized) client
        pool; returns the update strategies must migrate through, or
        ``None`` when nothing changed."""
        ...


class SimulatedEnvironment:
    """The Fig. 3 world: rounds cost what eqs. 6-7 say they cost.

    Exposes ``cost_model`` (scalar + swarm-vectorized evaluators) so
    swarm-mode drivers (``FlagSwapPSO.run`` with ``batch_fitness_fn``)
    ride the same object the step loop uses. The cost model reads the
    pool by reference — event schedules that mutate ``clients`` in place
    are reflected in the very next ``step``.

    The topology is ELASTIC: the hierarchy is a versioned run property,
    not a construction-time constant. After ``ClientJoin``/``ClientLeave``
    events resize the pool, :meth:`sync_topology` re-hierarchizes (via
    ``choose_fl_hierarchy``) whenever the population leaves the current
    tree's capacity window ``[min_clients, max_clients]``, bumps
    ``topology_version``, and retargets the cost model in place — the
    returned :class:`TopologyUpdate` carries the slot/client remaps the
    strategies' ``migrate`` hooks consume.
    """
    kind = "simulated"

    def __init__(self, hierarchy: Hierarchy, clients: ClientPool,
                 cost_model: Optional[CostModel] = None):
        self.hierarchy = hierarchy
        self.clients = clients
        self.cost_model = cost_model if cost_model is not None \
            else CostModel(hierarchy, clients)
        self.topology_version = 0
        # scenarios may start deliberately overstuffed (large-10k packs
        # ~7 trainers/leaf): the grow threshold honors the construction-
        # time population so a stray join doesn't snap the tree
        self._capacity = max(hierarchy.max_clients, len(clients))

    def begin(self) -> None:
        pass

    def sync_topology(self) -> Optional[TopologyUpdate]:
        """Reconcile hierarchy with the pool after this round's events.

        Drains the pool's resize log (composing the old->new client id
        remap). Any resize yields a new hierarchy — at minimum the
        client count changed — and the STRUCTURE is rebuilt through
        ``choose_fl_hierarchy`` when the population crossed the capacity
        window; within the window only ``n_clients`` is re-pinned (same
        tree, cheaper migration). Deterministic: no rng is consumed, so
        sequential and batched sweeps see identical updates.
        """
        drained = self.clients.drain_resizes()
        if drained is None:
            return None
        old_n, client_remap = drained
        old_h = self.hierarchy
        if old_n != old_h.total_clients:
            raise RuntimeError(
                f"pool resize log starts at {old_n} clients but the "
                f"hierarchy tracked {old_h.total_clients}")
        n = len(self.clients)
        # the shared capacity-window rule (fl.distributed): in-window
        # resizes keep the tree and re-pin the client count, crossings
        # rebuild the structure — identical on the emulated track
        new_h, self._capacity = elastic_rehierarchize(old_h, n,
                                                      self._capacity)
        self.topology_version += 1
        update = TopologyUpdate(
            version=self.topology_version,
            old_hierarchy=old_h, new_hierarchy=new_h,
            slot_remap=slot_remap(old_h, new_h),
            client_remap=client_remap)
        self.hierarchy = new_h
        self.cost_model.retarget(new_h)
        return update

    def step(self, round_idx: int, placement) -> RoundObservation:
        # single-placement fast path: the cached exact (float64 numpy)
        # vectorized evaluator — bit-identical to CostModel.tpd (pinned
        # by the parity suite), but the O(C) Python trainer/cluster
        # loops never run, which is what makes 1k-10k client scenarios
        # steppable at all
        placement = np.asarray(placement, np.int64)
        self.hierarchy.validate_placement(placement)
        tpd = self.cost_model.tpd_fast(placement)
        return RoundObservation(round_idx=round_idx, placement=placement,
                                tpd=tpd,
                                topology_version=self.topology_version)


class EmulatedEnvironment:
    """The Fig. 4 world: rounds cost what the federated run measures.

    Thin adapter over ``FederatedOrchestrator`` — ``step`` IS
    ``orchestrator.run_round``, so a strategy driven through this
    environment reproduces ``FederatedOrchestrator.run`` exactly
    (including model state evolution and eval metrics).

    The topology is ELASTIC, exactly like the simulated track:
    ``ClientJoin``/``ClientLeave`` events resize the orchestrator's live
    pool, and :meth:`sync_topology` delegates to
    ``FederatedOrchestrator.sync_population`` — survivors keep their
    model weights (the global model) and data shards, joiners are
    provisioned shards and train from the current global params, and the
    re-hierarchization rule is the SAME capacity-window logic, so one
    event schedule replays the identical hierarchy/``topology_version``
    sequence on both tracks.
    """
    kind = "emulated"

    def __init__(self, orchestrator):
        self.orchestrator = orchestrator
        self.clients = orchestrator.clients
        self._cost_model: Optional[CostModel] = None

    @property
    def hierarchy(self) -> Hierarchy:
        """The orchestrator's CURRENT hierarchy (elastic runs rebind it
        mid-flight, so this must never be snapshotted at construction)."""
        return self.orchestrator.hierarchy

    @property
    def topology_version(self) -> int:
        return self.orchestrator.topology_version

    @property
    def cost_model(self) -> CostModel:
        """Analytic eqs. 6-7 view of the same pool (lazily built) — only
        used as strategy-construction context (e.g. the exhaustive
        oracle); the observed TPD always comes from the orchestrator."""
        if self._cost_model is None:
            self._cost_model = CostModel(self.hierarchy, self.clients)
        return self._cost_model

    def begin(self) -> None:
        self.orchestrator.warmup()

    def sync_topology(self) -> Optional[TopologyUpdate]:
        """Reconcile the orchestrator with this round's pool resizes:
        data shards carried/provisioned, FedAvg weights recomputed, the
        round engine retargeted, and the returned update's
        slot/client remaps feed the strategies' ``migrate`` hooks (the
        runner calls them) — an aggregator-host departure is repaired
        before the next proposal."""
        update = self.orchestrator.sync_population()
        if update is not None and self._cost_model is not None:
            # keep the analytic view strategies hold a reference to
            # pointed at the live topology
            self._cost_model.retarget(update.new_hierarchy)
        return update

    def step(self, round_idx: int, placement) -> RoundObservation:
        rec = self.orchestrator.run_round(round_idx, placement)
        return RoundObservation(
            round_idx=round_idx,
            placement=np.asarray(rec.placement, np.int64),
            tpd=float(rec.tpd),
            metrics={"loss": rec.loss, "accuracy": rec.accuracy,
                     "train_time": rec.train_time,
                     "agg_time": rec.agg_time},
            topology_version=self.topology_version)


def build_environment(spec, seed: int = 0) -> Environment:
    """Materialize a ScenarioSpec into a fresh environment for one run."""
    hierarchy = spec.make_hierarchy()
    pool = spec.make_pool(seed)
    if spec.kind == "simulated":
        if spec.pods:
            n = hierarchy.total_clients
            pod_of = np.arange(n) * spec.pods // n
            cm = TwoTierCostModel(hierarchy, pool,
                                  memory_penalty=spec.memory_penalty,
                                  pod_of=pod_of, ici_cost=spec.ici_cost,
                                  dcn_cost=spec.dcn_cost)
        else:
            cm = CostModel(hierarchy, pool,
                           memory_penalty=spec.memory_penalty)
        return SimulatedEnvironment(hierarchy, pool, cm)

    # emulated: build model + data + orchestrator
    from repro.configs import get_config
    from repro.data.synthetic import make_federated_dataset
    from repro.fl.orchestrator import FederatedOrchestrator
    from repro.models import get_model

    cfg = get_config(spec.model)
    model = get_model(cfg)
    data = make_federated_dataset(cfg, hierarchy.total_clients, seed=seed)
    orch = FederatedOrchestrator(
        model, hierarchy, pool, data,
        local_steps=spec.local_steps, batch_size=spec.batch_size,
        seed=seed, comm_latency=spec.comm_latency, timing=spec.timing,
        engine=spec.engine)
    return EmulatedEnvironment(orch)
