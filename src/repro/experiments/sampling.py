"""Per-round client sampling: resident pool vs. per-round cohort.

Production cross-device FL never sees the whole population in one
round — a few hundred participants are drawn from a pool of millions
(see the HFL survey and Qolomany et al. in PAPERS.md; the swarm only
ever needs the sampled cohort). This module holds the sampling stream:
a :class:`CohortSampler` that draws each round's cohort from the
resident pool with a *counter-based* RNG, so the cohort sequence is a
pure function of ``(seed, round)`` — identical across sequential vs.
batched runners and across a checkpoint/resume boundary with no stream
state to serialize.

Stream discipline (RPL002): every draw seeds
``default_rng((seed, _SAMPLING_STREAM, round))`` — a named stream
constant, no literals in the seed expression, no process entropy.
"""
from __future__ import annotations

import numpy as np

__all__ = ["CohortSampler"]

# dedicated stream id for cohort draws, disjoint from the event
# (0xE7E47), arrival (0xA441), fault (0xFA175), elastic (0xE1A57) and
# eval (0xE7A1) streams
_SAMPLING_STREAM = 0x5A3B1E


class CohortSampler:
    """Draws the round-``r`` cohort from a pool of ``pool_n`` clients.

    ``draw`` is stateless: round ``r``'s cohort comes from its own
    counter-based stream, so replaying any round re-derives the same
    cohort regardless of execution order. Cohort ids are sorted so the
    gathered attribute arrays are in stable pool order.
    """

    def __init__(self, seed: int, cohort_size: int):
        if cohort_size < 2:
            raise ValueError(f"cohort_size must be >= 2, got {cohort_size}")
        self.seed = int(seed)
        self.cohort_size = int(cohort_size)

    def draw(self, round_idx: int, pool_n: int) -> np.ndarray:
        """Sorted pool indices of round ``round_idx``'s cohort
        (``min(cohort_size, pool_n)`` of them, without replacement)."""
        k = min(self.cohort_size, int(pool_n))
        rng = np.random.default_rng(
            (self.seed, _SAMPLING_STREAM, int(round_idx)))
        return np.sort(rng.choice(int(pool_n), size=k, replace=False))

    def migrate(self, client_remap: np.ndarray) -> None:
        """Pool resize hook (mirrors ``ArrivalProcess.migrate``).

        The stream is keyed on ``(seed, round)`` — not on client ids —
        so there is no per-client state to re-key: the next ``draw``
        simply ranges over the new pool size. Kept as an explicit hook
        so resize plumbing treats all streams uniformly.
        """

    def state_dict(self) -> dict:
        """Checkpoint payload — static config only; draws are
        counter-based so there is no stream position to save."""
        return {"seed": self.seed, "cohort_size": self.cohort_size}
