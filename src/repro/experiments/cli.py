"""Command-line front end:  PYTHONPATH=src python -m repro.experiments ...

Subcommands::

    list                        registered scenarios + strategies
    run SCENARIO                sweep strategies x seeds, write artifact
        --strategies pso,random --rounds 25 --seeds 0,17
        --set depth=4 --set width=5        (ScenarioSpec overrides)
        --env emulated                     (run on the other track, e.g.
                                            elastic presets on Fig. 4)
        --out artifacts/experiments/foo.json
    validate PATH [PATH ...]    schema-check existing artifacts

Exit status is non-zero on schema-invalid artifacts, so CI can use
``run`` + ``validate`` directly as a smoke gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core.registry import list_strategies
from repro.experiments.eval_config import EvalConfig
from repro.experiments.results import ExperimentResult, validate_result_dict
from repro.experiments.runner import aggregate_line, run_experiment
from repro.experiments.scenarios import get_scenario, list_scenarios

DEFAULT_OUT_DIR = Path("artifacts") / "experiments"


def _parse_set(pairs):
    out = {}
    for p in pairs or ():
        if "=" not in p:
            raise SystemExit(f"--set expects key=value, got {p!r}")
        k, v = p.split("=", 1)
        out[k.strip()] = v.strip()
    return out


def cmd_list(args) -> int:
    print("scenarios:")
    for spec in list_scenarios():
        events = ",".join(type(e).__name__ for e in spec.events) or "-"
        print(f"  {spec.name:12s} [{spec.kind:9s}] rounds={spec.rounds:<4d} "
              f"events={events}")
        print(f"               {spec.description}")
    print("\nstrategies:")
    for info in list_strategies():
        aliases = f" (aliases: {', '.join(info.aliases)})" \
            if info.aliases else ""
        fields = ", ".join(info.config_fields) or "-"
        print(f"  {info.name:12s} {info.description}{aliases}")
        print(f"               config: {fields}")
    return 0


def cmd_run(args) -> int:
    spec = get_scenario(args.scenario)
    if getattr(args, "env", None):
        spec = spec.for_env(args.env)
    overrides = _parse_set(args.set)
    # nested overrides: --set eval.backend=interpret targets EvalConfig,
    # everything else targets the ScenarioSpec
    eval_overrides = {k[len("eval."):]: v for k, v in overrides.items()
                      if k.startswith("eval.")}
    overrides = {k: v for k, v in overrides.items()
                 if not k.startswith("eval.")}
    try:
        eval_config = EvalConfig().with_overrides(**eval_overrides)
    except (TypeError, ValueError) as e:
        raise SystemExit(str(e)) from e
    if args.mode is not None:
        print("note: --mode is deprecated; use --set eval.mode=...")
        if "mode" in eval_overrides and eval_overrides["mode"] != args.mode:
            raise SystemExit(
                f"conflicting modes: --mode {args.mode} vs "
                f"--set eval.mode={eval_overrides['mode']}")
        eval_config = eval_config.with_overrides(mode=args.mode)
    if overrides:
        try:
            spec = spec.with_overrides(**overrides)
        except TypeError as e:
            raise SystemExit(str(e)) from e
    seeds = [int(s) for s in args.seeds.split(",") if s != ""]
    strategies = [s for s in args.strategies.split(",") if s]
    rounds = args.rounds if args.rounds is not None else spec.rounds

    print(f"== experiment {spec.name} [{spec.kind}] rounds={rounds} "
          f"seeds={seeds} strategies={strategies} "
          f"mode={eval_config.mode} ==")
    result = run_experiment(spec, strategies, rounds=rounds, seeds=seeds,
                            verbose=args.verbose, eval_config=eval_config)

    # --env runs get a kind-suffixed default filename, so driving the
    # same preset on both tracks never silently clobbers one artifact
    # with the other
    default_name = f"{spec.name}_{spec.kind}.json" \
        if getattr(args, "env", None) else f"{spec.name}.json"
    out = Path(args.out) if args.out else DEFAULT_OUT_DIR / default_name
    result.save(out)
    print(f"-> wrote {out} (schema v{result.stamped_schema_version()}, "
          f"{len(result.runs)} runs)")
    return 0


def cmd_validate(args) -> int:
    status = 0
    for p in args.paths:
        try:
            d = json.loads(Path(p).read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"{p}: UNREADABLE ({e})")
            status = 1
            continue
        errors = validate_result_dict(d)
        if errors:
            print(f"{p}: INVALID")
            for e in errors:
                print(f"  - {e}")
            status = 1
        else:
            result = ExperimentResult.from_dict(d)
            print(f"{p}: OK (scenario={result.scenario['name']}, "
                  f"rounds={result.rounds}, seeds={result.seeds}, "
                  f"strategies={result.strategies})")
            for s in result.strategies:
                print(f"  {s:12s} {aggregate_line(result, s)}")
    return status


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments",
        description="Unified placement-experiment runner")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="show registered scenarios + strategies")

    run_p = sub.add_parser("run", help="run a scenario sweep")
    run_p.add_argument("scenario", help="registered scenario name")
    run_p.add_argument("--strategies", default="pso,random,uniform",
                       help="comma-separated strategy names/aliases")
    run_p.add_argument("--rounds", type=int, default=None,
                       help="override the scenario's round budget")
    run_p.add_argument("--seeds", default="0",
                       help="comma-separated seeds (multi-seed sweep)")
    run_p.add_argument("--set", action="append", metavar="KEY=VALUE",
                       help="override a ScenarioSpec field, or an "
                            "EvalConfig field via the eval. prefix "
                            "(e.g. eval.backend=interpret, "
                            "eval.mode=batched, eval.recording=on; "
                            "repeatable)")
    run_p.add_argument("--env", default=None,
                       choices=("simulated", "emulated", "online"),
                       help="run the scenario on the given track "
                            "regardless of its registered kind (e.g. "
                            "the elastic presets on the emulated "
                            "Fig. 4 world)")
    run_p.add_argument("--out", default=None,
                       help=f"artifact path (default "
                            f"{DEFAULT_OUT_DIR}/<scenario>.json)")
    run_p.add_argument("--mode", default=None,
                       choices=("auto", "sequential", "batched"),
                       help="DEPRECATED alias for --set eval.mode=... "
                            "(batched = lockstep pooled evaluation, "
                            "simulated only; both modes are "
                            "bit-identical)")
    run_p.add_argument("--verbose", action="store_true")

    val_p = sub.add_parser("validate",
                           help="schema-check result artifacts")
    val_p.add_argument("paths", nargs="+")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return {"list": cmd_list, "run": cmd_run,
            "validate": cmd_validate}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
