"""EvalConfig: ONE frozen dataclass for every evaluation knob.

The knobs accreted across PRs — ``batch_tpd(backend=...)``,
``PooledTPDEvaluator(shard=...)``, the runner's ``mode=``, and the
calibrated-vs-analytic cost source — are consolidated here and threaded
through ``run_experiment`` / ``run_single`` / ``build_environment`` /
the CLI (``--set eval.backend=interpret`` style nested overrides)::

    from repro.experiments import EvalConfig, run_experiment
    run_experiment("paper-fig3", ["pso"],
                   eval_config=EvalConfig(mode="batched", shard="off"))

Two kinds of fields, deliberately separated:

* **execution knobs** (``mode``, ``shard``, ``recording``) — change HOW
  a sweep runs, never WHAT it computes; every combination is
  parity-pinned bit-identical, so they are NOT artifact provenance.
* **semantics knobs** (``backend``, ``cost_source``, ``calibration``) —
  can change the numbers a strategy observes; :meth:`provenance`
  returns exactly these (or ``None`` when all are default), and the
  result artifact stamps schema v4 only when the section is present —
  default-config artifacts stay byte-identical to pre-EvalConfig runs.

The legacy ``run_experiment(mode=..., shard=...)`` kwargs and the CLI
``--mode`` flag keep working for one release through deprecation shims
(:func:`resolve_eval_config`) that name the replacement field.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Dict, Optional

_MODES = ("auto", "sequential", "batched")
_BACKENDS = (None, "np", "jit", "pallas", "interpret")
_SHARDS = ("auto", "on", "off")
_COST_SOURCES = ("analytic", "calibrated")
_RECORDING = ("off", "on")


@dataclass(frozen=True)
class EvalConfig:
    """How a sweep evaluates placements.

    mode         sweep execution: 'auto' | 'sequential' | 'batched'
                 (recording='on' forces the sequential step loop)
    backend      pin the batch-TPD backend strategies ride inside the
                 PSO inner loop: None (auto) | 'np' | 'jit' | 'pallas'
                 | 'interpret'
    shard        pooled-evaluator device sharding: 'auto' | 'off'
    cost_source  'analytic' (paper eqs. 6-7) | 'calibrated'
                 (trace-fitted terms; simulated track only)
    calibration  path to a fitted-calibration JSON
                 (``python -m repro.calibration fit``) — required when
                 cost_source='calibrated'
    recording    'off' | 'on' — capture per-round timing traces into
                 ``RoundObservation.timings`` (byte-neutral: recorded
                 runs produce bit-identical artifacts)
    """
    mode: str = "auto"
    backend: Optional[str] = None
    shard: str = "auto"
    cost_source: str = "analytic"
    calibration: Optional[str] = None
    recording: str = "off"

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"unknown eval.mode {self.mode!r}; "
                             f"use one of {_MODES}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown eval.backend {self.backend!r}; "
                             f"use one of {_BACKENDS}")
        if self.shard not in _SHARDS:
            raise ValueError(f"unknown eval.shard {self.shard!r}; "
                             f"use one of {_SHARDS}")
        if self.cost_source not in _COST_SOURCES:
            raise ValueError(
                f"unknown eval.cost_source {self.cost_source!r}; "
                f"use one of {_COST_SOURCES}")
        if self.recording not in _RECORDING:
            raise ValueError(f"unknown eval.recording {self.recording!r}; "
                             f"use one of {_RECORDING}")
        if self.cost_source == "calibrated" and not self.calibration:
            raise ValueError(
                "eval.cost_source='calibrated' needs eval.calibration="
                "<path to a fitted-calibration JSON> (write one with "
                "`python -m repro.calibration fit`)")
        if self.recording == "on" and self.mode == "batched":
            raise ValueError(
                "eval.recording='on' needs the sequential step loop "
                "(batched mode bypasses env.step); use eval.mode="
                "'sequential' or 'auto'")

    # -- artifact provenance ------------------------------------------------
    def provenance(self) -> Optional[Dict[str, Any]]:
        """The semantics-bearing fields, for the result artifact's
        ``eval`` section — or ``None`` when every one is default.

        Execution knobs (mode/shard/recording) are EXCLUDED: they are
        parity-pinned bit-identical, and stamping them would make
        sequential and batched runs of the same sweep produce different
        bytes, breaking the golden artifact pins."""
        out: Dict[str, Any] = {}
        if self.backend is not None:
            out["backend"] = self.backend
        if self.cost_source != "analytic":
            out["cost_source"] = self.cost_source
            out["calibration"] = self.calibration
        return out or None

    # -- CLI-facing construction --------------------------------------------
    def with_overrides(self, **overrides) -> "EvalConfig":
        """``dataclasses.replace`` with CLI-friendly string coercion
        (``--set eval.backend=none`` clears the pin)."""
        by_name = {f.name for f in dataclasses.fields(self)}
        coerced = {}
        for k, v in overrides.items():
            if k not in by_name:
                accepted = ", ".join(sorted(by_name))
                raise TypeError(f"EvalConfig has no field {k!r}; "
                                f"fields: {accepted}")
            if isinstance(v, str) and v.lower() in ("none", "null"):
                v = None
            coerced[k] = v
        return dataclasses.replace(self, **coerced)


def resolve_eval_config(eval_config: Optional[EvalConfig] = None, *,
                        mode: Optional[str] = None,
                        shard: Optional[str] = None) -> EvalConfig:
    """Fold the legacy ``mode=``/``shard=`` kwargs into one EvalConfig.

    The legacy kwargs keep working for one release; each use warns with
    the replacement field's name. Passing a legacy kwarg that disagrees
    with an explicit ``eval_config`` is an error — silently preferring
    either would make the sweep run under a config the caller didn't
    write."""
    legacy = {}
    if mode is not None:
        warnings.warn(
            "the mode= kwarg is deprecated; use "
            "eval_config=EvalConfig(mode=...) (CLI: --set eval.mode=...)",
            DeprecationWarning, stacklevel=3)
        legacy["mode"] = mode
    if shard is not None:
        warnings.warn(
            "the shard= kwarg is deprecated; use "
            "eval_config=EvalConfig(shard=...) (CLI: --set eval.shard=...)",
            DeprecationWarning, stacklevel=3)
        legacy["shard"] = shard
    if eval_config is None:
        return EvalConfig(**legacy)
    for k, v in legacy.items():
        if getattr(eval_config, k) != v:
            raise ValueError(
                f"conflicting evaluation config: legacy kwarg {k}={v!r} "
                f"vs EvalConfig.{k}={getattr(eval_config, k)!r} — drop "
                f"the deprecated kwarg")
    return eval_config
