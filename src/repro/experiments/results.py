"""Versioned experiment-result artifact: one JSON schema for every
strategy x scenario x seed sweep (simulated and emulated alike).

Schema v2 layout (v1 artifacts still validate/load)::

    {
      "schema": "repro.experiments/result",
      "schema_version": 2,
      "scenario": {... ScenarioSpec.to_dict() ...},
      "rounds": 50,
      "seeds": [0, 17],
      "strategies": ["pso", "random"],
      "runs": [
        {"strategy": "pso", "seed": 0, "tpds": [...],
         "metrics": {"accuracy": [...], ...},
         "event_log": ["r60: pspeed drift (reverse)"],
         "total_tpd": ..., "mean_tpd": ..., "last10_mean_tpd": ...,
         "best_tpd": ..., "final_metrics": {"accuracy": ...}},
        ...
      ],
      "aggregates": {"pso": {"total_tpd": ..., "total_tpd_std": ...,
                             "mean_tpd": ..., "last10_mean_tpd": ...,
                             "best_tpd": ..., "final_accuracy": ...}, ...}
    }

v2 additions (all optional per run, so static artifacts are unchanged
apart from the version stamp):

* elastic runs carry a per-round ``metrics["topology_version"]`` series
  plus ``r<N>: topology vK: ...`` event-log lines (the environments
  re-hierarchize as the client population crosses capacity);
* ``strategy_state`` — a full strategy checkpoint captured by
  ``StrategyRun.save_state`` (swarm positions/velocities/pbest, rng
  stream, history), restorable with ``load_state`` for sweep resume.

v3 additions (the fault track; again optional per run, so fault-free
artifacts only change their version stamp):

* the scenario dict may carry ``faults`` (tagged fault-event dicts),
  ``fault_profile``, ``quorum_frac``, ``retry_limit`` and
  ``retry_backoff`` — v1/v2 artifacts without them load as fault-free;
* faulty runs carry per-round metric series: ``faults`` (cumulative
  injected events), ``dropped_updates``, ``retries`` (online only),
  ``degraded_flushes`` (quorum-refused merges), ``failovers``
  (aggregator re-homings), plus ``down``/``partitioned`` gauges.

v4 addition (EvalConfig provenance): a top-level ``eval`` object
carrying the semantics-bearing evaluation fields (``backend`` pin,
``cost_source``/``calibration``). The section — and therefore the v4
stamp — appears ONLY when a non-default field was set: a default-config
sweep still writes schema_version 3 with the exact pre-EvalConfig
bytes, so the golden artifact pins (and any downstream byte diffing)
survive the redesign. Execution knobs (mode/shard/recording) are never
stamped; they are parity-pinned bit-identical.

``validate_result_dict`` is the schema gate the CLI (and CI smoke job)
run before an artifact is written or consumed.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

RESULT_SCHEMA = "repro.experiments/result"
RESULT_SCHEMA_VERSION = 4
# what an artifact WITHOUT an eval section stamps (byte-compat with
# every pre-EvalConfig artifact)
_PRE_EVAL_SCHEMA_VERSION = 3
# older artifact versions that still validate and load
RESULT_SCHEMA_COMPAT = (1, 2, 3, 4)


@dataclass
class StrategyRun:
    """One (strategy, seed) trajectory through an environment."""
    strategy: str
    seed: int
    tpds: List[float] = field(default_factory=list)
    metrics: Dict[str, List[float]] = field(default_factory=dict)
    event_log: List[str] = field(default_factory=list)
    # optional end-of-run strategy internals (reignitions, evaluations,
    # converged, ...) — diagnostic only, not aggregated
    diagnostics: Dict[str, Any] = field(default_factory=dict)
    # optional full strategy checkpoint (schema v2): everything needed
    # to resume the strategy mid-sweep — see save_state/load_state
    strategy_state: Optional[Dict[str, Any]] = None

    # -- checkpointing -----------------------------------------------------
    def save_state(self, strategy) -> None:
        """Capture ``strategy``'s checkpoint (positions/velocities/pbest
        arrays, rng stream, swarm history — whatever the strategy's
        ``save_state`` serializes) into this run record."""
        self.strategy_state = strategy.save_state()

    def load_state(self, strategy) -> None:
        """Restore the captured checkpoint into ``strategy`` (exact
        resume: the rng stream continues where the checkpoint left it).
        """
        if self.strategy_state is None:
            raise ValueError(
                f"run ({self.strategy}, seed {self.seed}) carries no "
                f"strategy_state; re-run with capture_state=True")
        strategy.load_state(self.strategy_state)

    # -- derived ----------------------------------------------------------
    @property
    def total_tpd(self) -> float:
        return float(np.sum(self.tpds)) if self.tpds else 0.0

    @property
    def mean_tpd(self) -> float:
        return float(np.mean(self.tpds)) if self.tpds else 0.0

    @property
    def last10_mean_tpd(self) -> float:
        return float(np.mean(self.tpds[-10:])) if self.tpds else 0.0

    @property
    def best_tpd(self) -> float:
        return float(np.min(self.tpds)) if self.tpds else 0.0

    def final_metrics(self) -> Dict[str, float]:
        return {k: float(v[-1]) for k, v in self.metrics.items() if v}

    def to_dict(self) -> Dict[str, Any]:
        out = {
            "strategy": self.strategy, "seed": self.seed,
            "tpds": [float(t) for t in self.tpds],
            "metrics": {k: [float(x) for x in v]
                        for k, v in self.metrics.items()},
            "event_log": list(self.event_log),
            "diagnostics": dict(self.diagnostics),
            "total_tpd": self.total_tpd, "mean_tpd": self.mean_tpd,
            "last10_mean_tpd": self.last10_mean_tpd,
            "best_tpd": self.best_tpd,
            "final_metrics": self.final_metrics(),
        }
        if self.strategy_state is not None:
            out["strategy_state"] = self.strategy_state
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StrategyRun":
        return cls(strategy=d["strategy"], seed=int(d["seed"]),
                   tpds=list(d.get("tpds", [])),
                   metrics={k: list(v)
                            for k, v in d.get("metrics", {}).items()},
                   event_log=list(d.get("event_log", [])),
                   diagnostics=dict(d.get("diagnostics", {})),
                   strategy_state=d.get("strategy_state"))


def aggregate_runs(runs: List[StrategyRun]) -> Dict[str, float]:
    """Multi-seed aggregate for ONE strategy's runs."""
    if not runs:
        return {"n_seeds": 0, "total_tpd": 0.0, "total_tpd_std": 0.0,
                "mean_tpd": 0.0, "last10_mean_tpd": 0.0, "best_tpd": 0.0}
    totals = [r.total_tpd for r in runs]
    agg = {
        "n_seeds": len(runs),
        "total_tpd": float(np.mean(totals)),
        "total_tpd_std": float(np.std(totals)),
        "mean_tpd": float(np.mean([r.mean_tpd for r in runs])),
        "last10_mean_tpd": float(np.mean([r.last10_mean_tpd
                                          for r in runs])),
        "best_tpd": float(np.mean([r.best_tpd for r in runs])),
    }
    metric_keys = sorted({k for r in runs for k in r.final_metrics()})
    for k in metric_keys:
        vals = [r.final_metrics()[k] for r in runs
                if k in r.final_metrics()]
        agg[f"final_{k}"] = float(np.mean(vals))
    return agg


@dataclass
class ExperimentResult:
    """The full sweep artifact (see module docstring for the schema)."""
    scenario: Dict[str, Any]
    rounds: int
    seeds: List[int]
    strategies: List[str]
    runs: List[StrategyRun] = field(default_factory=list)
    # EvalConfig.provenance(): the semantics-bearing evaluation fields,
    # or None for a default config (then the artifact keeps the v3
    # bytes — the golden-pin invariant)
    eval: Optional[Dict[str, Any]] = None
    # None = stamp at serialization time from the eval section; loaded
    # artifacts keep their original stamp through a round trip
    schema_version: Optional[int] = None

    def runs_for(self, strategy: str) -> List[StrategyRun]:
        return [r for r in self.runs if r.strategy == strategy]

    @property
    def aggregates(self) -> Dict[str, Dict[str, float]]:
        return {s: aggregate_runs(self.runs_for(s))
                for s in self.strategies}

    def stamped_schema_version(self) -> int:
        if self.schema_version is not None:
            return self.schema_version
        return RESULT_SCHEMA_VERSION if self.eval is not None \
            else _PRE_EVAL_SCHEMA_VERSION

    # -- JSON round trip ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = {
            "schema": RESULT_SCHEMA,
            "schema_version": self.stamped_schema_version(),
            "scenario": self.scenario,
            "rounds": self.rounds,
            "seeds": list(self.seeds),
            "strategies": list(self.strategies),
        }
        if self.eval is not None:
            d["eval"] = dict(self.eval)
        d["runs"] = [r.to_dict() for r in self.runs]
        d["aggregates"] = self.aggregates
        return d

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 1)
        return json.dumps(self.to_dict(), **kw)

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        d = self.to_dict()
        errors = validate_result_dict(d)
        if errors:
            raise ValueError(f"refusing to write schema-invalid artifact: "
                             f"{errors}")
        path.write_text(json.dumps(d, indent=1))
        return path

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentResult":
        errors = validate_result_dict(d)
        if errors:
            raise ValueError(f"invalid experiment artifact: {errors}")
        return cls(
            scenario=d["scenario"], rounds=int(d["rounds"]),
            seeds=[int(s) for s in d["seeds"]],
            strategies=list(d["strategies"]),
            runs=[StrategyRun.from_dict(r) for r in d["runs"]],
            eval=d.get("eval"),
            schema_version=int(d["schema_version"]))

    @classmethod
    def load(cls, path) -> "ExperimentResult":
        return cls.from_dict(json.loads(Path(path).read_text()))


def validate_result_dict(d: Dict[str, Any]) -> List[str]:
    """Structural schema check; returns a list of problems (empty = ok)."""
    errors: List[str] = []
    if not isinstance(d, dict):
        return ["artifact is not a JSON object"]
    if d.get("schema") != RESULT_SCHEMA:
        errors.append(f"schema != {RESULT_SCHEMA!r}")
    if d.get("schema_version") not in RESULT_SCHEMA_COMPAT:
        errors.append(f"schema_version not in {RESULT_SCHEMA_COMPAT}")
    for key, typ in (("scenario", dict), ("rounds", int), ("seeds", list),
                     ("strategies", list), ("runs", list),
                     ("aggregates", dict)):
        if not isinstance(d.get(key), typ):
            errors.append(f"missing/mistyped field {key!r} "
                          f"(want {typ.__name__})")
    if errors:
        return errors
    if not isinstance(d["scenario"].get("name"), str):
        errors.append("scenario.name missing")
    if "eval" in d:
        if not isinstance(d["eval"], dict):
            errors.append("eval section must be an object")
        elif d["schema_version"] < 4:
            errors.append("eval section requires schema_version >= 4")
    expected_runs = len(d["strategies"]) * len(d["seeds"])
    if len(d["runs"]) != expected_runs:
        errors.append(f"expected {expected_runs} runs "
                      f"(strategies x seeds), got {len(d['runs'])}")
    for i, r in enumerate(d["runs"]):
        for key in ("strategy", "seed", "tpds", "total_tpd"):
            if key not in r:
                errors.append(f"runs[{i}] missing {key!r}")
        if r.get("strategy") not in d["strategies"]:
            errors.append(f"runs[{i}].strategy {r.get('strategy')!r} "
                          f"not in strategies")
        if len(r.get("tpds", [])) != d["rounds"]:
            errors.append(f"runs[{i}] has {len(r.get('tpds', []))} tpds, "
                          f"expected rounds={d['rounds']}")
    for s in d["strategies"]:
        if s not in d["aggregates"]:
            errors.append(f"aggregates missing strategy {s!r}")
    return errors
