"""Declarative experiment scenarios.

A :class:`ScenarioSpec` is everything needed to reconstruct one
evaluation world: the aggregation hierarchy, the client-pool profile,
the environment kind (``simulated`` = the paper's Fig. 3 analytical
`CostModel`; ``emulated`` = the Fig. 4 docker-cluster emulation via
`FederatedOrchestrator`; ``online`` = the same orchestrator under the
asynchronous discrete-event track of ``repro.online``), and a per-round
*event schedule* (pspeed
drift, client churn, straggler spikes, latency noise) that turns the
stationary paper setups into the adaptive scenarios the roadmap asks
for.

Presets registered here (``get_scenario`` / ``list_scenarios``):

==============  ==========  ====================================================
name            kind        what it reproduces / probes
==============  ==========  ====================================================
``paper-fig3``  simulated   one Fig. 3 grid cell (PSO vs. eqs. 6-7 TPD model)
``paper-fig4``  emulated    the 10-client heterogeneous docker cluster (Fig. 4)
``drift``       simulated   mid-run pspeed reversal (Sec. VI future work)
``churn``       simulated   periodic client replacement (device churn)
``straggler``   simulated   transient slowdown spikes on a client subset
``latency``     simulated   multiplicative noise on the observed TPD signal
``two-tier``    simulated   ICI/DCN pod topology (TwoTierCostModel)
``large-256``   simulated   256-client pool, depth-4 tree (scale smoke)
``large-1k``    simulated   1k clients, depth-6/width-3 (364 slots)
``large-4k``    simulated   4k clients, depth-5/width-4 (341 slots)
``large-10k``   simulated   10k clients, depth-6/width-4 (1365 slots)
``large-100k``  simulated   100k pool, 512-cohort/round sampling
``pool-1m``     simulated   1M pool, 1024-cohort/round sampling
``flash-crowd``     simulated  population ramps mid-run; tree re-grows
``composite-storm`` simulated  joins+leaves+churn+stragglers+noise at once
``ebb-and-flow``    simulated  periodic join/leave waves across capacity
``online-fig4``     online     Fig. 4 cluster asynchronously (jitter + buffers)
``online-straggler`` online    delay-triggered mid-round host re-optimization
``online-sync``     online     degenerate lockstep twin of paper-fig4 (parity)
``online-faulty``   online     online-fig4 under crashes/drops/degrades + retry
``chaos``           online     every fault kind at once, quorum-gated merges
==============  ==========  ====================================================

The last two carry a FAULT track (``repro.faults``): a seeded
:class:`~repro.faults.schedule.FaultProfile` draws a randomized-but-
replayable :class:`~repro.faults.schedule.FaultSchedule` per run
(``spec.make_faults(seed)``), and the tolerance knobs
(``retry_limit``/``retry_backoff``/``quorum_frac``) configure bounded
virtual-time retries and the quorum-gated degraded merge. A spec with
no profile and an empty ``faults`` tuple runs the exact pre-fault code
paths — bit-identical to the fault-free tracks (the parity pin).

The last three are ELASTIC: ``ClientJoin``/``ClientLeave`` events
genuinely resize the pool, and the environments re-hierarchize (new
``Hierarchy``, bumped ``topology_version``, strategy ``migrate`` hooks)
whenever the population crosses the current tree's capacity window.
They run on BOTH tracks: ``spec.for_env("emulated")`` (CLI
``--env emulated``) drives the same event schedule through the live
``FederatedOrchestrator`` — clients admitted/retired mid-run, joiners
training from the current global model — and replays the identical
hierarchy sequence the simulated track produces.

The ``large-*`` rungs are the swarm-scale regime: they are only
practical through the exact vectorized evaluators
(``CostModel.tpd_fast`` per step, ``PooledTPDEvaluator`` in the batched
sweep runner) — the scalar eq. 6/7 loop costs milliseconds per call at
these sizes (``benchmarks/bench_scale.py`` tracks the gap).

``large-100k``/``pool-1m`` add the SAMPLED regime on top: the spec's
``sampling``/``pool_size``/``cohort_size`` knobs keep a resident
:class:`ClientPool` of ``pool_size`` clients while every round draws a
``cohort_size`` cohort from a counter-based stream
(``repro.experiments.sampling``); the cohort — not the pool — drives
``choose_fl_hierarchy`` and the cost model, so memory is bounded by
the cohort. ``sampling='off'`` (the default everywhere else) runs the
exact pre-sampling code paths, byte-identical artifacts included.

Specs are frozen; derive variants with ``with_overrides(depth=4, ...)``
(the CLI's ``--set key=value`` goes through the same path).
"""
from __future__ import annotations

import copy
import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.hierarchy import ClientPool, Hierarchy
from repro.faults.schedule import (
    FaultEvent,
    FaultProfile,
    FaultSchedule,
    fault_from_dict,
)


# ---------------------------------------------------------------------------
# client-pool profiles
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PoolProfile:
    """How to build the ClientPool for a scenario.

    ``kind='random'`` samples the paper's Sec. IV-A distributions
    (memcap ~ U[10,50), pspeed ~ U[5,15)) per seed; ``kind='explicit'``
    pins every attribute (the Fig. 4 docker resource limits).
    """
    kind: str = "random"                 # 'random' | 'explicit'
    mdatasize: float = 5.0
    memcap: Optional[Tuple[float, ...]] = None
    pspeed: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.kind not in ("random", "explicit"):
            raise ValueError(f"unknown pool profile kind {self.kind!r}")
        if self.kind == "explicit" and (self.memcap is None
                                        or self.pspeed is None):
            raise ValueError("explicit pool profile needs memcap + pspeed")

    def make(self, n_clients: int, seed: int) -> ClientPool:
        if self.kind == "random":
            return ClientPool.random(n_clients, seed=seed,
                                     mdatasize=self.mdatasize)
        if len(self.pspeed) != n_clients or len(self.memcap) != n_clients:
            raise ValueError(
                f"explicit pool has {len(self.pspeed)} pspeed / "
                f"{len(self.memcap)} memcap entries, "
                f"scenario needs {n_clients} clients")
        return ClientPool(
            memcap=np.asarray(self.memcap, np.float64).copy(),
            pspeed=np.asarray(self.pspeed, np.float64).copy(),
            mdatasize=np.full(n_clients, self.mdatasize, np.float64))


# ---------------------------------------------------------------------------
# per-round event schedules
# ---------------------------------------------------------------------------
@dataclass
class ScheduledEvent:
    """Base event. Subclasses mutate the client pool before a round
    (``on_round``) and/or distort the observed delay (``transform_tpd``).

    Event instances in a spec are templates: the runner works on a
    ``fresh()`` copy per (strategy, seed) run so mutable state (e.g. a
    straggler's saved speeds) never leaks across runs.

    Same-round application order is deterministic and documented:
    within each round, events fire sorted by ``(class_name, index)`` —
    class name first, spec position breaking ties (``make_events``
    performs the stable sort once) — so composite schedules replay
    identically across the sequential and batched runners regardless of
    how the spec happened to list them.
    """

    # True for events that resize the population (ClientJoin/Leave):
    # the runners re-sync the topology after applying a round's events
    resizes_pool = False

    def fresh(self) -> "ScheduledEvent":
        return copy.deepcopy(self)

    def on_round(self, round_idx: int, pool: ClientPool,
                 rng: np.random.Generator) -> Optional[str]:
        """Mutate ``pool`` in place; return a log line or None."""
        return None

    def on_topology(self, update) -> None:
        """An elastic resize renumbered the population: events holding
        client-id-keyed state carry it through ``update.client_remap``
        (same :class:`~repro.core.hierarchy.TopologyUpdate` the strategy
        ``migrate`` hooks receive; the runners invoke this right after
        them, in both execution modes)."""
        return None

    def transform_tpd(self, round_idx: int, tpd: float,
                      rng: np.random.Generator) -> float:
        return tpd

    def state_dict(self) -> Dict[str, Any]:
        """JSON-safe mutable run state for checkpointing. Stateless
        events (most of them — the rng lives in the runner) return
        ``{}``; events carrying cross-round state (StragglerSpike's
        saved speeds) override both hooks."""
        return {}

    def load_state(self, state: Dict[str, Any]) -> None:
        return None

    def to_dict(self) -> Dict[str, Any]:
        d = {"event": type(self).__name__}
        d.update(dataclasses.asdict(self))
        return d


@dataclass
class PSpeedDrift(ScheduledEvent):
    """One-shot system drift at ``at_round``: client speeds are reversed
    (fast hosts become slow — the bench_drift scenario) or reshuffled."""
    at_round: int = 60
    mode: str = "reverse"                # 'reverse' | 'shuffle'

    def on_round(self, round_idx, pool, rng):
        if round_idx != self.at_round:
            return None
        if self.mode == "reverse":
            pool.pspeed = pool.pspeed[::-1].copy()
        elif self.mode == "shuffle":
            pool.pspeed = rng.permutation(pool.pspeed).copy()
        else:
            raise ValueError(f"unknown drift mode {self.mode!r}")
        return f"pspeed drift ({self.mode})"


@dataclass
class ClientChurn(ScheduledEvent):
    """Every ``every`` rounds a random ``fraction`` of clients leave and
    are replaced by fresh devices (attributes resampled from the paper's
    Sec. IV-A distributions)."""
    every: int = 10
    fraction: float = 0.25
    first_round: int = 1

    def on_round(self, round_idx, pool, rng):
        if round_idx < self.first_round or \
                (round_idx - self.first_round) % self.every != 0:
            return None
        n = len(pool)
        k = max(1, int(round(n * self.fraction)))
        who = rng.choice(n, size=k, replace=False)
        pool.memcap[who] = rng.uniform(10, 50, k)
        pool.pspeed[who] = rng.uniform(5, 15, k)
        pool.touch()  # in-place edit: bump the evaluator-cache version
        return f"churn: replaced {k} clients"


@dataclass
class StragglerSpike(ScheduledEvent):
    """Every ``every`` rounds a random ``fraction`` of clients slows
    down by ``slowdown``x for ``duration`` rounds, then recovers —
    container throttling / co-tenant interference."""
    every: int = 15
    duration: int = 5
    fraction: float = 0.2
    slowdown: float = 6.0
    first_round: int = 5
    # client -> (slowed value, original value); restoring checks the
    # slowed value is still in place so a concurrent event (churn
    # replacing the device, a drift reshuffle) that already rewrote the
    # client's speed is not clobbered by a stale recovery
    _saved: Dict[int, tuple] = field(default_factory=dict, repr=False)
    _until: int = field(default=-1, repr=False)

    def _rekey_saved(self, remap) -> None:
        if self._saved and remap is not None:
            self._saved = {int(remap[c]): v
                           for c, v in self._saved.items()
                           if c < len(remap) and remap[c] >= 0}

    def on_topology(self, update):
        # a resize renumbered the population mid-spike: re-key the saved
        # speeds so recovery restores the RIGHT (surviving) devices —
        # departed stragglers are simply forgotten
        self._rekey_saved(update.client_remap)

    def on_round(self, round_idx, pool, rng):
        if self._saved and round_idx >= self._until:
            # a SAME-round ClientLeave (canonical order puts it first)
            # may have renumbered the pool before this restore and the
            # end-of-round on_topology re-key: peek the pool's pending
            # resize log so the restore targets current indices
            self._rekey_saved(pool.pending_remap())
            restored = 0
            for c, (slowed, original) in self._saved.items():
                # belt and braces on top of on_topology's re-keying: the
                # index bound plus the slowed-value check keep a stale
                # recovery from touching the wrong device
                if c < len(pool) and pool.pspeed[c] == slowed:
                    pool.pspeed[c] = original
                    restored += 1
            self._saved = {}
            pool.touch()  # in-place edit: bump the cache version
            return f"stragglers recovered ({restored} clients)"
        if self._saved or round_idx < self.first_round or \
                (round_idx - self.first_round) % self.every != 0:
            return None
        n = len(pool)
        k = max(1, int(round(n * self.fraction)))
        who = rng.choice(n, size=k, replace=False)
        originals = {int(c): float(pool.pspeed[c]) for c in who}
        pool.pspeed[who] = pool.pspeed[who] / self.slowdown
        pool.touch()  # in-place edit: bump the cache version
        self._saved = {c: (float(pool.pspeed[c]), v)
                       for c, v in originals.items()}
        self._until = round_idx + self.duration
        return f"straggler spike: {k} clients {self.slowdown:g}x slower"

    def to_dict(self):
        d = super().to_dict()
        d.pop("_saved", None)
        d.pop("_until", None)
        return d

    def state_dict(self):
        return {"saved": [[int(c), float(slowed), float(orig)]
                          for c, (slowed, orig)
                          in sorted(self._saved.items())],
                "until": int(self._until)}

    def load_state(self, state):
        self._saved = {int(c): (float(slowed), float(orig))
                       for c, slowed, orig in state["saved"]}
        self._until = int(state["until"])


@dataclass
class ClientJoin(ScheduledEvent):
    """Every ``every`` rounds from ``first_round`` (through
    ``last_round``, when set), ``count`` fresh devices JOIN the pool —
    a true population resize (arrays grow, new ids are minted), not the
    attribute masking ``ClientChurn`` does. Attributes are sampled from
    the paper's Sec. IV-A distributions. The environments re-hierarchize
    when the growth crosses the tree's capacity (flash crowds)."""
    resizes_pool = True
    every: int = 10
    count: int = 4
    first_round: int = 5
    last_round: Optional[int] = None

    def on_round(self, round_idx, pool, rng):
        if round_idx < self.first_round or \
                (round_idx - self.first_round) % self.every != 0:
            return None
        if self.last_round is not None and round_idx > self.last_round:
            return None
        pool.join(memcap=rng.uniform(10, 50, self.count),
                  pspeed=rng.uniform(5, 15, self.count))
        return f"join: +{self.count} clients (pool now {len(pool)})"


@dataclass
class ClientLeave(ScheduledEvent):
    """Every ``every`` rounds from ``first_round``, ``count`` random
    clients LEAVE the pool — a true resize: survivors are renumbered and
    the composed old->new id remap flows through the topology update to
    every strategy's ``migrate`` hook. Departures can take out current
    aggregator hosts; the strategies repair such placements. Never
    shrinks the pool below ``min_clients``."""
    resizes_pool = True
    every: int = 10
    count: int = 4
    first_round: int = 10
    last_round: Optional[int] = None
    min_clients: int = 8

    def on_round(self, round_idx, pool, rng):
        if round_idx < self.first_round or \
                (round_idx - self.first_round) % self.every != 0:
            return None
        if self.last_round is not None and round_idx > self.last_round:
            return None
        k = min(self.count, len(pool) - self.min_clients)
        if k <= 0:
            return None
        who = rng.choice(len(pool), size=k, replace=False)
        pool.leave(who)
        return f"leave: -{k} clients (pool now {len(pool)})"


@dataclass
class LatencyNoise(ScheduledEvent):
    """Multiplicative lognormal-ish noise on the observed TPD — the
    black-box signal the strategy sees gets dirtier, the true system
    stays put (tests optimizer robustness to measurement noise)."""
    sigma: float = 0.1

    def transform_tpd(self, round_idx, tpd, rng):
        return float(tpd * max(1.0 + rng.normal(0.0, self.sigma), 1e-3))


_EVENT_TYPES = {cls.__name__: cls for cls in
                (PSpeedDrift, ClientChurn, StragglerSpike, LatencyNoise,
                 ClientJoin, ClientLeave)}


def event_from_dict(d: Dict[str, Any]) -> ScheduledEvent:
    d = dict(d)
    name = d.pop("event", None)
    cls = _EVENT_TYPES.get(name)
    if cls is None:
        known = ", ".join(sorted(_EVENT_TYPES))
        raise ValueError(f"unknown event type {name!r}; known: {known}")
    return cls(**d)


# ---------------------------------------------------------------------------
# the scenario spec
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ScenarioSpec:
    """One declarative experiment world (see module docstring)."""
    name: str
    kind: str                            # 'simulated' | 'emulated'
    depth: int = 3
    width: int = 2
    trainers_per_leaf: int = 2
    n_clients: Optional[int] = None
    pool: PoolProfile = field(default_factory=PoolProfile)
    events: Tuple[ScheduledEvent, ...] = ()
    rounds: int = 100                    # default round budget
    description: str = ""

    # simulated-only knobs
    memory_penalty: float = 0.0
    pods: Optional[int] = None           # two-tier topology: pod count
    ici_cost: float = 0.005
    dcn_cost: float = 0.05

    # emulated/online knobs (online runs the same orchestrator)
    model: str = "paper-mlp-1m8"
    local_steps: int = 2
    batch_size: int = 32
    comm_latency: float = 0.0
    timing: str = "deterministic"
    engine: str = "auto"

    # online-only knobs (see repro.online.async_fedavg.AsyncConfig)
    jitter: float = 0.0                  # lognormal sigma on train delays
    staleness_alpha: float = 0.5         # (1 + s)^(-alpha) decay
    flush_fraction: float = 1.0          # buffer count-flush fraction
    flush_timeout: float = 0.0           # virtual-time deadline (0 = off)
    server_lr: float = 1.0               # eta at the root merge
    reopt_threshold: float = 0.0         # flush-latency trigger (0 = off)
    reopt_beta: float = 0.5              # EWMA decay for observed delays

    # fault track (repro.faults; emulated + online kinds)
    faults: Tuple[FaultEvent, ...] = ()  # explicit pinned fault events
    fault_profile: Optional[FaultProfile] = None   # seeded generation
    quorum_frac: float = 0.0             # 0 = merge whatever arrived
    retry_limit: int = 0                 # retries per dropped update
    retry_backoff: float = 0.25          # virtual-time backoff base

    # client sampling (simulated track; repro.experiments.sampling):
    # the resident pool holds pool_size clients, each round draws a
    # cohort_size cohort from a counter-based stream; the COHORT drives
    # the hierarchy and the cost model, so memory scales with the
    # cohort, not the pool. "off" = full participation (the pre-
    # sampling code paths, byte-identical artifacts).
    sampling: str = "off"                # 'off' | 'uniform'
    pool_size: Optional[int] = None      # resident pool (sampling only)
    cohort_size: int = 0                 # per-round participants

    def __post_init__(self):
        if self.kind not in ("simulated", "emulated", "online"):
            raise ValueError(f"unknown scenario kind {self.kind!r}")
        if self.sampling not in ("off", "uniform"):
            raise ValueError(f"unknown sampling mode {self.sampling!r}; "
                             f"use 'off' or 'uniform'")
        if self.sampling != "off":
            if self.kind != "simulated":
                raise ValueError("client sampling is simulated-only "
                                 f"(kind={self.kind!r})")
            if self.pods is not None:
                raise ValueError("client sampling does not compose with "
                                 "the two-tier pod topology yet")
            if self.cohort_size < 2:
                raise ValueError(f"sampling needs cohort_size >= 2, "
                                 f"got {self.cohort_size}")
            if self.pool_size is None or self.pool_size < self.cohort_size:
                raise ValueError(
                    f"sampling needs pool_size >= cohort_size "
                    f"({self.pool_size} vs {self.cohort_size})")

    # -- construction ------------------------------------------------------
    def make_hierarchy(self) -> Hierarchy:
        if self.sampling != "off":
            # the cohort drives the tree: pick the scale-ladder shape
            # that fits cohort_size clients, exactly as the elastic
            # re-hierarchization will mid-run
            from repro.fl.distributed import choose_fl_hierarchy
            return choose_fl_hierarchy(self.cohort_size, scale=True)
        return Hierarchy(depth=self.depth, width=self.width,
                         trainers_per_leaf=self.trainers_per_leaf,
                         n_clients=self.n_clients)

    def make_pool(self, seed: int) -> ClientPool:
        if self.sampling != "off":
            return self.pool.make(int(self.pool_size), seed)
        return self.pool.make(self.make_hierarchy().total_clients, seed)

    def make_sampler(self, seed: int):
        """The run's :class:`~repro.experiments.sampling.CohortSampler`
        (None when sampling is off)."""
        if self.sampling == "off":
            return None
        from repro.experiments.sampling import CohortSampler
        return CohortSampler(seed, self.cohort_size)

    def make_environment(self, seed: int = 0, eval_config=None):
        """Build a fresh Environment for one (strategy, seed) run.
        ``eval_config`` (an :class:`~repro.experiments.EvalConfig`)
        selects cost source / backend pin / timing recording."""
        from repro.experiments.environments import build_environment
        return build_environment(self, seed, eval_config=eval_config)

    def make_faults(self, seed: int) -> FaultSchedule:
        """The run's fault schedule: the spec's explicit pinned events
        plus (when a :class:`FaultProfile` is set) the randomized-but-
        seeded events drawn from the dedicated fault stream — a pure
        function of (spec, seed), so every faulty run replays."""
        events = tuple(self.faults)
        if self.fault_profile is not None:
            hier = self.make_hierarchy()
            gen = FaultSchedule.generate(
                self.fault_profile, seed=seed,
                n_clients=hier.total_clients, n_slots=hier.dimensions,
                rounds=self.rounds)
            events = events + gen.events
        return FaultSchedule(events)

    def make_events(self) -> Tuple[ScheduledEvent, ...]:
        """Fresh per-run event copies in the CANONICAL application
        order: stable-sorted by ``(class_name, spec index)``, so a
        composite schedule fires identically every run, in every
        execution mode, however the spec listed its events."""
        fresh = [e.fresh() for e in self.events]
        return tuple(sorted(fresh, key=lambda e: type(e).__name__))

    @property
    def is_elastic(self) -> bool:
        """Does any scheduled event resize the client population?"""
        return any(e.resizes_pool for e in self.events)

    def for_env(self, kind: str) -> "ScenarioSpec":
        """The same scenario on the other evaluation track.

        ``for_env('emulated')`` runs a (possibly elastic) simulated
        preset on the Fig. 4 world — real local training via
        ``FederatedOrchestrator``, with the track-specific knobs
        (``model``, ``local_steps``, ``timing``, ...) taking their
        spec'd values; ``for_env('simulated')`` goes the other way;
        ``for_env('online')`` lifts any preset onto the asynchronous
        event-driven track (with its ``jitter``/``flush_*``/``reopt_*``
        knobs at their spec'd values — a preset that never set them runs
        the degenerate lockstep config, bit-identical to emulated). The
        CLI's ``--env`` flag routes through here.
        """
        if kind not in ("simulated", "emulated", "online"):
            raise ValueError(f"unknown environment kind {kind!r}")
        if kind == self.kind:
            return self
        return dataclasses.replace(self, kind=kind)

    # -- variants ----------------------------------------------------------
    def with_overrides(self, **overrides) -> "ScenarioSpec":
        """``dataclasses.replace`` with CLI-friendly string coercion."""
        coerced = {}
        by_name = {f.name: f for f in dataclasses.fields(self)}
        for k, v in overrides.items():
            if k not in by_name:
                accepted = ", ".join(sorted(by_name))
                raise TypeError(f"scenario {self.name!r} has no field "
                                f"{k!r}; fields: {accepted}")
            try:
                if k == "fault_profile":
                    coerced[k] = _coerce_profile(v)
                else:
                    coerced[k] = _coerce(v, getattr(self, k))
            except ValueError:
                raise TypeError(
                    f"cannot parse {k}={v!r} for scenario "
                    f"{self.name!r} (current value "
                    f"{getattr(self, k)!r})") from None
        return dataclasses.replace(self, **coerced)

    # -- serialization (for the versioned result artifact) -----------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["pool"] = dataclasses.asdict(self.pool)
        d["events"] = [e.to_dict() for e in self.events]
        d["faults"] = [f.to_dict() for f in self.faults]
        d["fault_profile"] = (None if self.fault_profile is None
                              else self.fault_profile.to_dict())
        if self.sampling == "off":
            # sampling-free artifacts keep the pre-sampling schema
            # byte-identical (the parity pin in tests/golden/)
            for k in ("sampling", "pool_size", "cohort_size"):
                d.pop(k, None)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScenarioSpec":
        d = dict(d)
        d["pool"] = PoolProfile(**d.get("pool", {}))
        d["events"] = tuple(event_from_dict(e) for e in d.get("events", ()))
        # schema v1/v2 artifacts predate the fault track: absent keys
        # mean the fault-free defaults
        d["faults"] = tuple(fault_from_dict(f) for f in d.get("faults", ()))
        fp = d.get("fault_profile")
        d["fault_profile"] = None if fp is None else FaultProfile.from_dict(fp)
        return cls(**d)


def _coerce(value, current):
    """Coerce a CLI string to the field's current type.

    Scalars coerce by the current value's type; TUPLE fields (the event
    schedule above all) parse as JSON — a list of ``{"event": ...}``
    dicts becomes a tuple of :class:`ScheduledEvent` via
    ``event_from_dict``, any other JSON list becomes a plain tuple, and
    ``""``/``none``/``[]``/``()`` clear the field — so
    ``--set 'events=[{"event":"ClientJoin","count":4}]'`` works from
    the command line.
    """
    if not isinstance(value, str) or isinstance(current, str):
        return value
    if isinstance(current, bool):
        return value.lower() in ("1", "true", "yes", "on")
    if isinstance(current, tuple):
        return _coerce_sequence(value)
    if isinstance(current, int) or (current is None and value.isdigit()):
        return int(value)
    if isinstance(current, float):
        return float(value)
    if current is None:
        try:
            return int(value)
        except ValueError:
            return value
    return value


def _coerce_sequence(value: str) -> tuple:
    """Parse a CLI string for a tuple-typed ScenarioSpec field (see
    :func:`_coerce`). Raises ``ValueError`` on malformed input, which
    ``with_overrides`` turns into the usual descriptive TypeError."""
    v = value.strip()
    if v.lower() in ("", "none", "()", "[]"):
        return ()
    parsed = json.loads(v)  # JSONDecodeError is a ValueError
    if not isinstance(parsed, list):
        raise ValueError(f"expected a JSON list, got {type(parsed).__name__}")
    if parsed and all(isinstance(e, dict) for e in parsed):
        # tagged dicts: {"fault": ...} -> FaultEvent, {"event": ...}
        # -> ScheduledEvent (so --set 'faults=[{"fault":"ClientCrash",
        # "client":3,"at_round":5}]' works from the command line)
        return tuple(fault_from_dict(e) if "fault" in e
                     else event_from_dict(e) for e in parsed)
    return tuple(parsed)


def _coerce_profile(value) -> Optional[FaultProfile]:
    """Coerce a ``fault_profile`` override: passthrough for None /
    FaultProfile, a JSON object string from the CLI (``""``/``none``
    clears it), or a plain dict."""
    if value is None or isinstance(value, FaultProfile):
        return value
    if isinstance(value, dict):
        return FaultProfile.from_dict(value)
    v = str(value).strip()
    if v.lower() in ("", "none", "{}"):
        return None
    parsed = json.loads(v)  # JSONDecodeError is a ValueError
    if not isinstance(parsed, dict):
        raise ValueError(
            f"expected a JSON object, got {type(parsed).__name__}")
    return FaultProfile.from_dict(parsed)


# ---------------------------------------------------------------------------
# preset registry
# ---------------------------------------------------------------------------
_SCENARIOS: Dict[str, ScenarioSpec] = {}


def register_scenario(spec: ScenarioSpec) -> ScenarioSpec:
    key = spec.name.lower()
    if key in _SCENARIOS:
        raise ValueError(f"scenario {spec.name!r} registered twice")
    _SCENARIOS[key] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    spec = _SCENARIOS.get(name.lower())
    if spec is None:
        known = ", ".join(sorted(_SCENARIOS))
        raise KeyError(f"unknown scenario {name!r}; registered: {known}")
    return spec


def list_scenarios() -> Tuple[ScenarioSpec, ...]:
    return tuple(_SCENARIOS.values())


# the Fig. 4 docker resource limits -> relative speed units (one beefy,
# two medium, seven tiny containers; see bench_fig4_cluster)
_FIG4_PSPEED = (4.0, 2.0, 2.0) + (1.0,) * 7
_FIG4_MEMCAP = (2048.0, 1024.0, 1024.0) + (64.0,) * 7

register_scenario(ScenarioSpec(
    name="paper-fig3", kind="simulated", depth=3, width=4,
    trainers_per_leaf=2, rounds=100,
    description="One Fig. 3 grid cell: PSO against the eqs. 6-7 TPD "
                "cost model, paper Sec. IV-A client distributions."))

register_scenario(ScenarioSpec(
    name="paper-fig4", kind="emulated", depth=2, width=2,
    trainers_per_leaf=1, n_clients=10,
    pool=PoolProfile(kind="explicit", mdatasize=30.0,
                     memcap=_FIG4_MEMCAP, pspeed=_FIG4_PSPEED),
    rounds=50, model="paper-mlp-1m8", local_steps=2, batch_size=32,
    comm_latency=0.002, timing="deterministic",
    description="The 10-client heterogeneous docker/MQTT cluster "
                "(Fig. 4), emulated single-host."))

register_scenario(ScenarioSpec(
    name="drift", kind="simulated", depth=3, width=2, trainers_per_leaf=2,
    events=(PSpeedDrift(at_round=60, mode="reverse"),), rounds=180,
    description="Client speeds reversed at round 60: the 'container got "
                "throttled' drift scenario (paper Sec. VI)."))

register_scenario(ScenarioSpec(
    name="churn", kind="simulated", depth=3, width=2, trainers_per_leaf=2,
    n_clients=24, events=(ClientChurn(every=10, fraction=0.25),),
    rounds=120,
    description="A quarter of the pool replaced by fresh devices every "
                "10 rounds."))

register_scenario(ScenarioSpec(
    name="straggler", kind="simulated", depth=3, width=2,
    trainers_per_leaf=2, n_clients=24,
    events=(StragglerSpike(every=15, duration=5, fraction=0.2,
                           slowdown=6.0),),
    rounds=120,
    description="Transient 6x slowdown spikes on 20% of clients."))

register_scenario(ScenarioSpec(
    name="latency", kind="simulated", depth=3, width=2,
    trainers_per_leaf=2, events=(LatencyNoise(sigma=0.15),), rounds=120,
    description="15% multiplicative noise on the observed TPD signal."))

register_scenario(ScenarioSpec(
    name="two-tier", kind="simulated", depth=3, width=2,
    trainers_per_leaf=2, n_clients=24, pods=2, rounds=150,
    description="Two TPU pods: cross-pod aggregation edges pay DCN "
                "rates (~10x ICI); probes black-box locality discovery."))

register_scenario(ScenarioSpec(
    name="large-256", kind="simulated", depth=4, width=3,
    trainers_per_leaf=2, n_clients=256, rounds=150,
    description="256-client pool on a depth-4/width-3 tree (40 slots): "
                "the scale smoke for placement search."))

register_scenario(ScenarioSpec(
    name="flash-crowd", kind="simulated", depth=2, width=2,
    trainers_per_leaf=4, n_clients=12,
    events=(ClientJoin(every=5, count=6, first_round=10, last_round=40),),
    rounds=80,
    description="Population ramps 12 -> ~54 mid-run: the tree re-grows "
                "(depth-2 -> -3 -> -4, D 3 -> 7 -> 15) as the flash "
                "crowd crosses each capacity window; swarms migrate "
                "instead of restarting."))

register_scenario(ScenarioSpec(
    name="composite-storm", kind="simulated", depth=2, width=2,
    trainers_per_leaf=4, n_clients=14,
    events=(ClientJoin(every=12, count=5, first_round=6),
            ClientLeave(every=18, count=6, first_round=18,
                        min_clients=11),
            ClientChurn(every=10, fraction=0.2, first_round=4),
            StragglerSpike(every=15, duration=4, fraction=0.2,
                           slowdown=5.0, first_round=5),
            LatencyNoise(sigma=0.1)),
    rounds=80,
    description="Everything at once: joins, departures, device churn, "
                "straggler spikes and observation noise — the composite "
                "adaptive scenario the roadmap asks for."))

register_scenario(ScenarioSpec(
    name="ebb-and-flow", kind="simulated", depth=2, width=2,
    trainers_per_leaf=4, n_clients=12,
    events=(ClientJoin(every=20, count=8, first_round=10),
            ClientLeave(every=20, count=8, first_round=20,
                        min_clients=11),),
    rounds=100,
    description="Periodic join/leave waves oscillating across the "
                "capacity boundary: the topology re-hierarchizes every "
                "~10 rounds (the migrate-vs-cold-restart benchmark)."))

register_scenario(ScenarioSpec(
    name="large-1k", kind="simulated", depth=6, width=3,
    trainers_per_leaf=2, n_clients=1024, rounds=100,
    description="1k-client pool on a depth-6/width-3 tree (364 slots, "
                "~2.7 trainers/leaf — the paper's small-cluster regime "
                "at scale); the bench_scale 20x-vs-scalar reference "
                "point."))

register_scenario(ScenarioSpec(
    name="large-4k", kind="simulated", depth=5, width=4,
    trainers_per_leaf=2, n_clients=4096, rounds=60,
    description="4k-client pool on a depth-5/width-4 tree (341 slots, "
                "~14.7 trainers/leaf — the stuffed-leaves regime): mid "
                "swarm-scale rung."))

register_scenario(ScenarioSpec(
    name="large-10k", kind="simulated", depth=6, width=4,
    trainers_per_leaf=2, n_clients=10000, rounds=50,
    description="10k-client pool on a depth-6/width-4 tree (1365 "
                "slots): the paper's 'many clients as candidates' "
                "regime — a 50-round PSO run completes in seconds on "
                "CPU."))

register_scenario(ScenarioSpec(
    name="large-100k", kind="simulated", sampling="uniform",
    pool_size=100_000, cohort_size=512, rounds=60,
    description="100k-client resident pool, 512-client sampled cohort "
                "per round (depth-5/width-3, 121 slots): the first "
                "cross-device rung — memory scales with the cohort, "
                "not the pool."))

register_scenario(ScenarioSpec(
    name="pool-1m", kind="simulated", sampling="uniform",
    pool_size=1_000_000, cohort_size=1024, rounds=20,
    description="1M-client resident pool, 1024-client cohort per round "
                "(the large-1k tree, 364 slots): the production "
                "cross-device regime — the swarm only ever sees the "
                "cohort; pool attributes stay resident (~24 MB)."))

register_scenario(ScenarioSpec(
    name="online-fig4", kind="online", depth=2, width=2,
    trainers_per_leaf=1, n_clients=10,
    pool=PoolProfile(kind="explicit", mdatasize=30.0,
                     memcap=_FIG4_MEMCAP, pspeed=_FIG4_PSPEED),
    rounds=50, model="paper-mlp-1m8", local_steps=2, batch_size=32,
    comm_latency=0.002, timing="deterministic",
    jitter=0.35, staleness_alpha=0.5, flush_fraction=0.75,
    flush_timeout=0.5, server_lr=0.7,
    description="The Fig. 4 cluster asynchronously: jittered arrivals, "
                "75%-count-or-deadline buffer flushes, staleness-"
                "weighted merges — rounds overlap, stragglers land "
                "late with decayed weight."))

register_scenario(ScenarioSpec(
    name="online-straggler", kind="online", depth=3, width=2,
    trainers_per_leaf=2, n_clients=24,
    events=(StragglerSpike(every=15, duration=5, fraction=0.3,
                           slowdown=8.0),),
    rounds=60, comm_latency=0.002,
    jitter=0.25, staleness_alpha=0.5, flush_fraction=0.75,
    flush_timeout=0.5, server_lr=0.7,
    reopt_threshold=2.0, reopt_beta=0.5,
    description="The delay-triggered re-optimization demo: recurring "
                "8x straggler spikes blow a host's flush latency past "
                "2x its EWMA, and the environment swaps the host for "
                "the fastest observed unplaced client MID-ROUND "
                "(placement changes off the round boundary; the next "
                "sync_topology pulses strategies' migrate hooks)."))

register_scenario(ScenarioSpec(
    name="online-sync", kind="online", depth=2, width=2,
    trainers_per_leaf=1, n_clients=10,
    pool=PoolProfile(kind="explicit", mdatasize=30.0,
                     memcap=_FIG4_MEMCAP, pspeed=_FIG4_PSPEED),
    rounds=50, model="paper-mlp-1m8", local_steps=2, batch_size=32,
    comm_latency=0.002, timing="deterministic",
    description="paper-fig4's degenerate online twin: zero jitter, "
                "full-cohort flushes, no deadline — the event queue "
                "runs but every round is lockstep, bit-identical to "
                "the emulated track (the parity pin)."))

register_scenario(ScenarioSpec(
    name="online-faulty", kind="online", depth=2, width=2,
    trainers_per_leaf=1, n_clients=10,
    pool=PoolProfile(kind="explicit", mdatasize=30.0,
                     memcap=_FIG4_MEMCAP, pspeed=_FIG4_PSPEED),
    rounds=50, model="paper-mlp-1m8", local_steps=2, batch_size=32,
    comm_latency=0.002, timing="deterministic",
    jitter=0.35, staleness_alpha=0.5, flush_fraction=0.75,
    flush_timeout=0.5, server_lr=0.7,
    fault_profile=FaultProfile(crash_rate=0.15, crash_down_rounds=2,
                               drop_rate=0.25, degrade_rate=0.2,
                               degrade_factor=4.0, degrade_rounds=2,
                               agg_fail_every=10, agg_down_rounds=1,
                               first_round=2),
    retry_limit=3, retry_backoff=0.25, quorum_frac=0.2,
    description="online-fig4 under a seeded fault profile: client "
                "crashes void in-flight updates, transit drops retry "
                "with bounded virtual-time backoff, degraded links "
                "multiply delivery latency, and every 10th round the "
                "host of a random slot fails over mid-round; root "
                "flushes below the 20% quorum are refused (degraded "
                "flush, the model holds), at-or-above quorum they "
                "commit with a participation-damped server step."))

register_scenario(ScenarioSpec(
    name="chaos", kind="online", depth=2, width=2,
    trainers_per_leaf=1, n_clients=10,
    pool=PoolProfile(kind="explicit", mdatasize=30.0,
                     memcap=_FIG4_MEMCAP, pspeed=_FIG4_PSPEED),
    rounds=40, model="paper-mlp-1m8", local_steps=2, batch_size=32,
    comm_latency=0.002, timing="deterministic",
    jitter=0.3, staleness_alpha=0.5, flush_fraction=0.75,
    flush_timeout=0.5, server_lr=0.7,
    events=(StragglerSpike(every=12, duration=3, fraction=0.2,
                           slowdown=5.0, first_round=6),),
    fault_profile=FaultProfile(crash_rate=0.2, crash_down_rounds=2,
                               drop_rate=0.3, degrade_rate=0.25,
                               degrade_factor=5.0, degrade_rounds=2,
                               partition_rate=0.15, partition_frac=0.3,
                               partition_rounds=1, agg_fail_every=8,
                               agg_down_rounds=1, first_round=2),
    retry_limit=2, retry_backoff=0.25, quorum_frac=0.2,
    description="Every fault kind at once on the tiny Fig. 4 topology "
                "(so even exhaustive search completes): crashes, "
                "drops, link degradation, timed network partitions "
                "that hold in-flight updates until they heal, cadenced "
                "aggregator failovers, plus straggler spikes — the "
                "survivability stress all registered strategies must "
                "ride out with a valid placement every round."))
