"""repro.experiments — the unified experiment API.

Three concepts:

* :class:`~repro.experiments.scenarios.ScenarioSpec` — a declarative
  description of an evaluation world (hierarchy, client-pool profile,
  event schedule), with registered presets for both paper figures and
  the beyond-paper drift/churn/straggler/latency/two-tier/large-256
  scenarios.
* ``Environment`` — one propose/observe protocol;
  :class:`SimulatedEnvironment` wraps the analytical CostModel (Fig. 3),
  :class:`EmulatedEnvironment` wraps the FederatedOrchestrator (Fig. 4),
  :class:`OnlineEnvironment` drives the same orchestrator through the
  asynchronous discrete-event track (``repro.online``). Every
  PlacementStrategy runs identically in all three worlds.
* :func:`run_experiment` — the multi-seed sweep runner producing one
  versioned :class:`ExperimentResult` JSON artifact, also exposed as a
  CLI: ``python -m repro.experiments run paper-fig4 --strategies
  pso,random --rounds 25 --seeds 0,17``.
"""
from repro.core.hierarchy import TopologyUpdate
from repro.experiments.eval_config import EvalConfig, resolve_eval_config
from repro.experiments.environments import (
    EmulatedEnvironment,
    Environment,
    OnlineEnvironment,
    RoundObservation,
    SimulatedEnvironment,
    build_environment,
)
from repro.experiments.results import (
    RESULT_SCHEMA,
    RESULT_SCHEMA_VERSION,
    ExperimentResult,
    StrategyRun,
    aggregate_runs,
    validate_result_dict,
)
from repro.experiments.runner import run_batched, run_experiment, run_single
from repro.experiments.scenarios import (
    ClientChurn,
    ClientJoin,
    ClientLeave,
    LatencyNoise,
    PoolProfile,
    PSpeedDrift,
    ScenarioSpec,
    ScheduledEvent,
    StragglerSpike,
    get_scenario,
    list_scenarios,
    register_scenario,
)

__all__ = [
    "Environment", "SimulatedEnvironment", "EmulatedEnvironment",
    "OnlineEnvironment", "RoundObservation", "TopologyUpdate",
    "build_environment", "EvalConfig", "resolve_eval_config",
    "ExperimentResult", "StrategyRun", "aggregate_runs",
    "validate_result_dict", "RESULT_SCHEMA", "RESULT_SCHEMA_VERSION",
    "run_experiment", "run_single", "run_batched",
    "ScenarioSpec", "PoolProfile", "ScheduledEvent", "PSpeedDrift",
    "ClientChurn", "ClientJoin", "ClientLeave",
    "StragglerSpike", "LatencyNoise",
    "get_scenario", "list_scenarios", "register_scenario",
]
