"""Replay: re-score recorded rounds under a calibration.

The replay harness walks a :class:`TraceArtifact`'s records and, for
each round, predicts what the cost model says the round SHOULD have
cost — per-cluster delays through the vectorized surrogate
(:func:`~repro.calibration.fit.batch_predict_cluster_delay`), the
round's aggregation delay as the sum of per-level maxima (paper eq. 7),
and the training phase as ``train_scale * max(1/pspeed)`` over the
round's recorded trainers — then compares against the delays the
emulated engine actually charged. The result is a per-round /
per-level delay prediction error report: the sim-to-real gap,
quantified.

Replaying the neutral :data:`~repro.calibration.fit.ANALYTIC`
calibration scores the paper's analytic model against the same trace,
so ``report`` (and ``bench_calibration --validate``) can assert that a
trace-fitted model strictly reduces held-out delay error.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.calibration.fit import (
    CalibrationResult,
    batch_predict_cluster_delay,
)
from repro.calibration.trace import TraceArtifact

REPLAY_SCHEMA = "repro.calibration/replay"
REPLAY_SCHEMA_VERSION = 1


@dataclass
class ReplayReport:
    """Per-round and per-level measured-vs-predicted delay errors."""
    calibration: Dict[str, Any]
    trace_source: Dict[str, Any]
    rounds: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def mean_abs_error(self) -> float:
        errs = [r["abs_error"] for r in self.rounds]
        return float(np.mean(errs)) if errs else 0.0

    @property
    def max_abs_error(self) -> float:
        errs = [r["abs_error"] for r in self.rounds]
        return float(np.max(errs)) if errs else 0.0

    @property
    def rms_error(self) -> float:
        errs = [r["abs_error"] for r in self.rounds]
        return float(np.sqrt(np.mean(np.square(errs)))) if errs else 0.0

    def per_level_mean_abs_error(self) -> Dict[int, float]:
        acc: Dict[int, List[float]] = {}
        for r in self.rounds:
            for lvl in r["levels"]:
                acc.setdefault(int(lvl["level"]), []).append(
                    abs(lvl["measured"] - lvl["predicted"]))
        return {k: float(np.mean(v)) for k, v in sorted(acc.items())}

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": REPLAY_SCHEMA,
            "schema_version": REPLAY_SCHEMA_VERSION,
            "calibration": self.calibration,
            "trace_source": self.trace_source,
            "summary": {
                "n_rounds": len(self.rounds),
                "mean_abs_error": self.mean_abs_error,
                "rms_error": self.rms_error,
                "max_abs_error": self.max_abs_error,
                "per_level_mean_abs_error": {
                    str(k): v
                    for k, v in self.per_level_mean_abs_error().items()},
            },
            "rounds": self.rounds,
        }

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=1))
        return path


def replay(trace: TraceArtifact, calibration: CalibrationResult, *,
           rounds: Optional[Sequence[int]] = None) -> ReplayReport:
    """Score ``calibration``'s delay predictions against a trace.

    ``rounds`` restricts the replay to specific round indices (e.g. the
    holdout tail the fitter never saw); default is every recorded round.
    """
    pspeed = np.asarray(trace.clients["pspeed"], dtype=np.float64)
    wanted = None if rounds is None else {int(r) for r in rounds}
    out_rounds: List[Dict[str, Any]] = []
    for rec in trace.records:
        if wanted is not None and int(rec["round"]) not in wanted:
            continue
        level_rows: List[Dict[str, Any]] = []
        pred_agg = 0.0
        meas_agg = 0.0
        for lvl in rec["levels"]:
            level = int(lvl["level"])
            hosts = np.asarray(lvl["hosts"], dtype=np.int64)
            pred = batch_predict_cluster_delay(
                lvl["loads"], pspeed[hosts], lvl["n_parts"],
                np.full(len(hosts), level), calibration)
            meas_level = float(np.max(lvl["delays"])) if hosts.size else 0.0
            pred_level = float(np.max(pred)) if hosts.size else 0.0
            level_rows.append({
                "level": level,
                "measured": meas_level,
                "predicted": pred_level,
                "cluster_mean_abs_error": float(
                    np.mean(np.abs(pred - np.asarray(lvl["delays"]))))
                if hosts.size else 0.0,
            })
            pred_agg += pred_level
            meas_agg += meas_level
        train = rec["train"]
        trainers = np.asarray(train["clients"], dtype=np.int64)
        pred_train = (calibration.train_scale
                      * float(np.max(1.0 / pspeed[trainers]))
                      if trainers.size else 0.0)
        measured = float(rec["train_time"]) + float(rec["agg_time"])
        predicted = pred_train + pred_agg
        out_rounds.append({
            "round": int(rec["round"]),
            "measured": measured,
            "predicted": predicted,
            "abs_error": abs(measured - predicted),
            "train_measured": float(rec["train_time"]),
            "train_predicted": pred_train,
            "agg_measured": meas_agg,
            "agg_predicted": pred_agg,
            "levels": level_rows,
        })
    return ReplayReport(
        calibration=calibration.to_dict(),
        trace_source={
            "scenario": trace.scenario.get("name"),
            "kind": trace.kind,
            "strategy": trace.strategy,
            "seed": trace.seed,
            "rounds": trace.rounds,
        },
        rounds=out_rounds)


def format_report(tag: str, report: ReplayReport) -> str:
    """One human-readable block per replayed calibration."""
    lines = [f"[{tag}] {len(report.rounds)} rounds: "
             f"mean|err|={report.mean_abs_error:.6g} "
             f"rms={report.rms_error:.6g} "
             f"max|err|={report.max_abs_error:.6g}"]
    for level, err in report.per_level_mean_abs_error().items():
        lines.append(f"  level {level}: mean|err|={err:.6g}")
    for r in report.rounds:
        lines.append(
            f"  round {r['round']:>3}: measured={r['measured']:.6g} "
            f"predicted={r['predicted']:.6g} |err|={r['abs_error']:.6g}")
    return "\n".join(lines)
