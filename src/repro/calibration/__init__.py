"""repro.calibration — trace-calibrated cost models.

The sim-to-real loop in three moves:

1. **record** — run an emulated scenario with
   ``EvalConfig(recording='on')`` and write the per-client /
   per-cluster timings as a versioned :class:`TraceArtifact`
   (:func:`record_trace`; byte-neutral — recorded runs produce
   bit-identical result artifacts).
2. **fit** — least-squares recover the engine's delay laws from the
   trace (:func:`fit_calibration` → :class:`CalibrationResult`), and
   materialize them as a
   :class:`~repro.core.cost_model.CalibratedCostModel` usable anywhere
   the analytic model goes, including the PSO inner loop
   (``CostModel.from_trace`` delegates here).
3. **replay** — re-score recorded rounds under any calibration and
   report per-round/per-level delay prediction error
   (:func:`replay`); the neutral :data:`ANALYTIC` calibration scores
   the paper's closed-form model as the baseline.

CLI: ``python -m repro.calibration record|fit|replay|report|validate``.
"""
from repro.calibration.fit import (
    ANALYTIC,
    CALIBRATION_SCHEMA,
    CALIBRATION_SCHEMA_VERSION,
    CalibrationResult,
    batch_predict_cluster_delay,
    cost_model_from_trace,
    fit_calibration,
    load_calibration,
)
from repro.calibration.replay import (
    REPLAY_SCHEMA,
    REPLAY_SCHEMA_VERSION,
    ReplayReport,
    format_report,
    replay,
)
from repro.calibration.trace import (
    TRACE_SCHEMA,
    TRACE_SCHEMA_VERSION,
    TraceArtifact,
    record_trace,
    validate_trace_dict,
)

__all__ = [
    "TraceArtifact", "record_trace", "validate_trace_dict",
    "TRACE_SCHEMA", "TRACE_SCHEMA_VERSION",
    "CalibrationResult", "fit_calibration", "load_calibration",
    "cost_model_from_trace", "batch_predict_cluster_delay",
    "ANALYTIC", "CALIBRATION_SCHEMA", "CALIBRATION_SCHEMA_VERSION",
    "ReplayReport", "replay", "format_report",
    "REPLAY_SCHEMA", "REPLAY_SCHEMA_VERSION",
]
