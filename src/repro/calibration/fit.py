"""Fit CostModel parameters from a recorded trace.

The emulated engine's delay laws are linear in three parameters:

* cluster aggregation (one row per recorded cluster)::

      delay = alpha * load / pspeed_host + beta_level * n_parts

  with ``alpha`` the payload scale (the engine's eq. 6 divisor is
  ``1/alpha``) and one ``beta`` link charge per hierarchy level;
* local training (one row per recorded client)::

      time = gamma / pspeed_client

  with ``gamma`` the per-round local-step count.

So a single :func:`numpy.linalg.lstsq` over the trace's rows recovers
the engine's true constants exactly on deterministic-timing traces and
least-squares-optimally on noisy ones. The fitted
:class:`CalibrationResult` plugs into
:class:`~repro.core.cost_model.CalibratedCostModel` (via
:meth:`CalibrationResult.make_cost_model` or
``CostModel.from_trace``), which the PSO inner loop consumes through
the existing batch-TPD path.

The cheap vectorized surrogate :func:`batch_predict_cluster_delay`
scores many candidate clusters at once; its scalar oracle
``_predict_cluster_delay_ref`` is registered as an RPL001 parity pair.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.calibration.trace import TraceArtifact

CALIBRATION_SCHEMA = "repro.calibration/calibration"
CALIBRATION_SCHEMA_VERSION = 1


@dataclass(frozen=True)
class CalibrationResult:
    """Fitted CostModel parameters plus fit diagnostics.

    payload_scale  alpha: multiplier on load/pspeed (analytic = 1.0)
    level_link     beta per hierarchy level, indexed by level value
                   (one per-part link charge; analytic = all zero)
    train_scale    gamma: local-train time is gamma/pspeed (analytic 0)
    n_rows         fitted rows (clusters + clients) across kept rounds
    rms_residual   root-mean-square fit residual over those rows
    source         provenance: scenario/strategy/seed/rounds/holdout
    """
    payload_scale: float
    level_link: Tuple[float, ...]
    train_scale: float
    n_rows: int = 0
    rms_residual: float = 0.0
    source: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": CALIBRATION_SCHEMA,
            "schema_version": CALIBRATION_SCHEMA_VERSION,
            "payload_scale": self.payload_scale,
            "level_link": list(self.level_link),
            "train_scale": self.train_scale,
            "n_rows": self.n_rows,
            "rms_residual": self.rms_residual,
            "source": self.source,
        }

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=1))
        return path

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CalibrationResult":
        if d.get("schema") != CALIBRATION_SCHEMA:
            raise ValueError(
                f"not a calibration artifact (schema={d.get('schema')!r}, "
                f"want {CALIBRATION_SCHEMA!r})")
        if d.get("schema_version") != CALIBRATION_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported calibration schema_version "
                f"{d.get('schema_version')!r}")
        return cls(
            payload_scale=float(d["payload_scale"]),
            level_link=tuple(float(x) for x in d["level_link"]),
            train_scale=float(d["train_scale"]),
            n_rows=int(d.get("n_rows", 0)),
            rms_residual=float(d.get("rms_residual", 0.0)),
            source=dict(d.get("source", {})))

    def make_cost_model(self, hierarchy, clients, *,
                        memory_penalty: float = 1e6):
        """A :class:`CalibratedCostModel` carrying these parameters."""
        from repro.core.cost_model import CalibratedCostModel
        return CalibratedCostModel(
            hierarchy, clients, memory_penalty=memory_penalty,
            payload_scale=self.payload_scale,
            level_link=self.level_link,
            train_scale=self.train_scale)


#: the analytic cost model expressed as a (neutral) calibration: unit
#: payload scale, no link charges, no train term — the replay harness's
#: baseline.
ANALYTIC = CalibrationResult(payload_scale=1.0, level_link=(),
                             train_scale=0.0)


def load_calibration(path) -> CalibrationResult:
    """Read a fitted-calibration JSON written by
    :meth:`CalibrationResult.save` / ``python -m repro.calibration fit``."""
    return CalibrationResult.from_dict(json.loads(Path(path).read_text()))


def _split_rounds(trace: TraceArtifact,
                  holdout_rounds: int) -> Tuple[List[dict], List[dict]]:
    if holdout_rounds < 0:
        raise ValueError("holdout_rounds must be >= 0")
    if holdout_rounds >= len(trace.records):
        raise ValueError(
            f"holdout_rounds={holdout_rounds} leaves no fitting rounds "
            f"(trace has {len(trace.records)})")
    if holdout_rounds == 0:
        return list(trace.records), []
    return (list(trace.records[:-holdout_rounds]),
            list(trace.records[-holdout_rounds:]))


def fit_calibration(trace: TraceArtifact, *,
                    holdout_rounds: int = 0) -> CalibrationResult:
    """Least-squares fit of (payload_scale, level_link, train_scale)
    from a trace's cluster and train rows.

    ``holdout_rounds`` reserves the trace's LAST n rounds for replay
    validation — they contribute no fitting rows, so the replay error
    on them is a genuine held-out measurement.
    """
    fit_records, _ = _split_rounds(trace, holdout_rounds)
    pspeed = np.asarray(trace.clients["pspeed"], dtype=np.float64)
    depth = int(trace.hierarchy["depth"])

    # unknowns: [alpha, beta_0 .. beta_{depth-1}, gamma]
    n_unknown = 1 + depth + 1
    rows: List[np.ndarray] = []
    y: List[float] = []
    for rec in fit_records:
        for lvl in rec["levels"]:
            level = int(lvl["level"])
            for host, load, n_parts, delay in zip(
                    lvl["hosts"], lvl["loads"], lvl["n_parts"],
                    lvl["delays"]):
                x = np.zeros(n_unknown)
                x[0] = float(load) / pspeed[int(host)]
                x[1 + level] = float(n_parts)
                rows.append(x)
                y.append(float(delay))
        train = rec["train"]
        for client, t in zip(train["clients"], train["times"]):
            x = np.zeros(n_unknown)
            x[-1] = 1.0 / pspeed[int(client)]
            rows.append(x)
            y.append(float(t))
    if not rows:
        raise ValueError(
            "trace has no timing rows to fit — was it recorded with "
            "eval.recording='on' on the emulated track?")

    X = np.stack(rows)
    yv = np.asarray(y, dtype=np.float64)
    # drop all-zero columns (levels never observed) so lstsq stays
    # well-posed; their betas are pinned to 0
    seen = np.abs(X).sum(axis=0) > 0
    theta = np.zeros(n_unknown)
    sol, _, _, _ = np.linalg.lstsq(X[:, seen], yv, rcond=None)
    theta[seen] = sol
    resid = X @ theta - yv
    return CalibrationResult(
        payload_scale=float(theta[0]),
        level_link=tuple(float(b) for b in theta[1:1 + depth]),
        train_scale=float(theta[-1]),
        n_rows=int(len(yv)),
        rms_residual=float(np.sqrt(np.mean(resid ** 2))),
        source={
            "scenario": trace.scenario.get("name"),
            "kind": trace.kind,
            "strategy": trace.strategy,
            "seed": trace.seed,
            "rounds": trace.rounds,
            "holdout_rounds": holdout_rounds,
        })


def cost_model_from_trace(trace, *, hierarchy=None, clients=None,
                          holdout_rounds: int = 0):
    """``CostModel.from_trace`` backend: fit a trace, return the
    calibrated model. ``hierarchy``/``clients`` default to the trace's
    own recorded topology and pool."""
    if isinstance(trace, (str, Path)):
        trace = TraceArtifact.load(trace)
    cal = fit_calibration(trace, holdout_rounds=holdout_rounds)
    if hierarchy is None:
        from repro.core.hierarchy import Hierarchy
        hinfo = trace.hierarchy
        hierarchy = Hierarchy(
            depth=int(hinfo["depth"]), width=int(hinfo["width"]),
            trainers_per_leaf=int(hinfo["trainers_per_leaf"]),
            n_clients=int(hinfo["n_clients"]))
    if clients is None:
        from repro.core.hierarchy import ClientPool
        c = trace.clients
        clients = ClientPool(
            memcap=np.asarray(c["memcap"], dtype=np.float64),
            pspeed=np.asarray(c["pspeed"], dtype=np.float64),
            mdatasize=np.asarray(c["mdatasize"], dtype=np.float64))
    mp = float(trace.scenario.get("memory_penalty", 1e6))
    return cal.make_cost_model(hierarchy, clients, memory_penalty=mp)


# -- cluster-delay surrogate (RPL001 pair) ---------------------------------

def batch_predict_cluster_delay(loads, host_pspeed, n_parts, levels,
                                calibration: CalibrationResult):
    """Vectorized calibrated cluster-delay prediction.

    Scores many candidate clusters at once inside search loops without
    materializing CalibratedCostModel objects: for each row i,

        delay_i = alpha * loads[i]/host_pspeed[i] + beta_{levels[i]} *
                  n_parts[i]

    Levels the calibration never observed charge beta = 0. Parity pair:
    ``_predict_cluster_delay_ref`` is the scalar oracle (RPL001).
    """
    loads = np.asarray(loads, dtype=np.float64)
    host_pspeed = np.asarray(host_pspeed, dtype=np.float64)
    n_parts = np.asarray(n_parts, dtype=np.float64)
    levels = np.asarray(levels, dtype=np.int64)
    beta = np.zeros(int(levels.max()) + 1 if levels.size else 1)
    link = np.asarray(calibration.level_link, dtype=np.float64)
    beta[:min(len(beta), link.size)] = link[:len(beta)]
    return (calibration.payload_scale * loads / host_pspeed
            + beta[levels] * n_parts)


def _predict_cluster_delay_ref(load, host_pspeed, n_parts, level,
                               calibration: CalibrationResult) -> float:
    """Scalar oracle for :func:`batch_predict_cluster_delay`."""
    link = calibration.level_link
    beta = link[level] if level < len(link) else 0.0
    return (calibration.payload_scale * float(load) / float(host_pspeed)
            + beta * float(n_parts))
