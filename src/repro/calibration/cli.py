"""CLI for the trace-calibration loop.

    python -m repro.calibration record paper-fig4 --out trace.json
    python -m repro.calibration fit trace.json --out cal.json --holdout 1
    python -m repro.calibration replay trace.json --calibration cal.json
    python -m repro.calibration report trace.json --calibration cal.json
    python -m repro.calibration validate trace.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.calibration.fit import (
    ANALYTIC,
    fit_calibration,
    load_calibration,
)
from repro.calibration.replay import format_report, replay
from repro.calibration.trace import (
    TraceArtifact,
    record_trace,
    validate_trace_dict,
)


def _parse_rounds(text: Optional[str]) -> Optional[List[int]]:
    if text is None:
        return None
    return [int(tok) for tok in text.split(",") if tok.strip()]


def cmd_record(args) -> int:
    from repro.experiments.scenarios import get_scenario
    spec = get_scenario(args.scenario)
    overrides = {}
    for pair in args.set or ():
        if "=" not in pair:
            raise SystemExit(f"--set expects key=value, got {pair!r}")
        k, v = pair.split("=", 1)
        overrides[k.strip()] = v.strip()
    if overrides:
        try:
            spec = spec.with_overrides(**overrides)
        except TypeError as e:
            raise SystemExit(str(e)) from e
    trace = record_trace(spec, args.strategy, seed=args.seed,
                         rounds=args.rounds, verbose=args.verbose)
    path = trace.save(args.out)
    print(f"recorded {trace.rounds} rounds of "
          f"{trace.scenario.get('name')}/{trace.strategy} seed={trace.seed}"
          f" -> {path}")
    return 0


def cmd_fit(args) -> int:
    trace = TraceArtifact.load(args.trace)
    cal = fit_calibration(trace, holdout_rounds=args.holdout)
    path = cal.save(args.out)
    link = ", ".join(f"{b:.6g}" for b in cal.level_link)
    print(f"fit {cal.n_rows} rows: payload_scale={cal.payload_scale:.6g} "
          f"level_link=[{link}] train_scale={cal.train_scale:.6g} "
          f"rms_residual={cal.rms_residual:.3g} -> {path}")
    return 0


def cmd_replay(args) -> int:
    trace = TraceArtifact.load(args.trace)
    cal = (load_calibration(args.calibration)
           if args.calibration else ANALYTIC)
    tag = args.calibration or "analytic"
    report = replay(trace, cal, rounds=_parse_rounds(args.rounds))
    print(format_report(tag, report))
    if args.out:
        path = report.save(args.out)
        print(f"wrote {path}")
    return 0


def cmd_report(args) -> int:
    """Side-by-side: fitted calibration vs the analytic baseline."""
    trace = TraceArtifact.load(args.trace)
    rounds = _parse_rounds(args.rounds)
    cal = (load_calibration(args.calibration) if args.calibration
           else fit_calibration(trace, holdout_rounds=args.holdout))
    fitted = replay(trace, cal, rounds=rounds)
    analytic = replay(trace, ANALYTIC, rounds=rounds)
    print(format_report("calibrated", fitted))
    print(format_report("analytic", analytic))
    better = fitted.mean_abs_error < analytic.mean_abs_error
    print(f"calibrated mean|err|={fitted.mean_abs_error:.6g} vs "
          f"analytic {analytic.mean_abs_error:.6g} -> "
          f"{'calibrated wins' if better else 'analytic wins'}")
    return 0


def cmd_validate(args) -> int:
    d = json.loads(Path(args.trace).read_text())
    errors = validate_trace_dict(d)
    if errors:
        for e in errors:
            print(f"INVALID: {e}")
        return 1
    print(f"{args.trace}: valid {d['schema']} v{d['schema_version']} "
          f"({d['rounds']} rounds)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.calibration",
        description="record / fit / replay trace-calibrated cost models")
    sub = parser.add_subparsers(dest="command", required=True)

    p = sub.add_parser("record", help="record a timing trace")
    p.add_argument("scenario")
    p.add_argument("--strategy", default="pso")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--set", action="append", metavar="KEY=VALUE",
                   help="override a ScenarioSpec field (repeatable), "
                        "e.g. --set model=mlp-smoke")
    p.add_argument("--out", required=True)
    p.add_argument("--verbose", action="store_true")
    p.set_defaults(fn=cmd_record)

    p = sub.add_parser("fit", help="fit CostModel parameters from a trace")
    p.add_argument("trace")
    p.add_argument("--out", required=True)
    p.add_argument("--holdout", type=int, default=0,
                   help="reserve the trace's last N rounds (no fit rows)")
    p.set_defaults(fn=cmd_fit)

    p = sub.add_parser("replay",
                       help="score a calibration against a trace")
    p.add_argument("trace")
    p.add_argument("--calibration", default=None,
                   help="fitted-calibration JSON (default: analytic)")
    p.add_argument("--rounds", default=None,
                   help="comma-separated round indices (default: all)")
    p.add_argument("--out", default=None)
    p.set_defaults(fn=cmd_replay)

    p = sub.add_parser("report",
                       help="calibrated-vs-analytic error comparison")
    p.add_argument("trace")
    p.add_argument("--calibration", default=None,
                   help="fitted JSON (default: fit the trace now)")
    p.add_argument("--holdout", type=int, default=0)
    p.add_argument("--rounds", default=None)
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("validate", help="schema-check a trace artifact")
    p.add_argument("trace")
    p.set_defaults(fn=cmd_validate)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
