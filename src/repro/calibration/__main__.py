import sys

from repro.calibration.cli import main

sys.exit(main())
