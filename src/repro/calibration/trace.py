"""Trace recording: every emulated run becomes a reusable measurement.

A :class:`TraceArtifact` is the versioned, seeded-run-keyed record of
one (scenario, strategy, seed) run's timings — per-client local-step
times and per-level/per-cluster aggregation delays, exactly as the
environments surface them through ``RoundObservation.timings`` (the
uniform mapping all four environment kinds populate). The recorder is
byte-neutral: it reads values the engines already computed, consumes no
rng, and a ``recording=off`` run writes artifacts bit-identical to
pre-recording code (the golden pins in ``tests/golden/``).

Artifact layout (JSON, deterministic ordering)::

    {
      "schema": "repro.calibration/trace",
      "schema_version": 1,
      "scenario": {... ScenarioSpec.to_dict() ...},
      "kind": "emulated", "strategy": "pso", "seed": 0, "rounds": 3,
      "comm_latency": 0.002, "local_steps": 2,
      "clients": {"pspeed": [...], "mdatasize": [...], "memcap": [...]},
      "hierarchy": {"depth": 2, "width": 2, "trainers_per_leaf": 1,
                    "n_clients": 10},
      "records": [
        {"round": 0, "placement": [...], "tpd": ...,
         "train_time": ..., "agg_time": ...,
         "train": {"clients": [...], "times": [...]},
         "levels": [{"level": 1, "slots": [...], "hosts": [...],
                     "loads": [...], "n_parts": [...],
                     "delays": [...]}, ...]},
        ...
      ]
    }

``loads`` are RAW payload sums (mdatasize units, before the emulated
engine's eq. 6 scale) — the fitter's feature, never a fitted quantity.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

TRACE_SCHEMA = "repro.calibration/trace"
TRACE_SCHEMA_VERSION = 1


@dataclass
class TraceArtifact:
    """One recorded run's timing trace (see module docstring)."""
    scenario: Dict[str, Any]
    kind: str
    strategy: str
    seed: int
    rounds: int
    comm_latency: float
    local_steps: int
    clients: Dict[str, List[float]]
    hierarchy: Dict[str, int]
    records: List[Dict[str, Any]] = field(default_factory=list)
    schema_version: int = TRACE_SCHEMA_VERSION

    # -- JSON round trip ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": TRACE_SCHEMA,
            "schema_version": self.schema_version,
            "scenario": self.scenario,
            "kind": self.kind,
            "strategy": self.strategy,
            "seed": self.seed,
            "rounds": self.rounds,
            "comm_latency": self.comm_latency,
            "local_steps": self.local_steps,
            "clients": self.clients,
            "hierarchy": self.hierarchy,
            "records": self.records,
        }

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 1)
        return json.dumps(self.to_dict(), **kw)

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        d = self.to_dict()
        errors = validate_trace_dict(d)
        if errors:
            raise ValueError(
                f"refusing to write schema-invalid trace: {errors}")
        path.write_text(json.dumps(d, indent=1))
        return path

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceArtifact":
        errors = validate_trace_dict(d)
        if errors:
            raise ValueError(f"invalid trace artifact: {errors}")
        return cls(
            scenario=d["scenario"], kind=d["kind"],
            strategy=d["strategy"], seed=int(d["seed"]),
            rounds=int(d["rounds"]),
            comm_latency=float(d["comm_latency"]),
            local_steps=int(d["local_steps"]),
            clients=d["clients"], hierarchy=d["hierarchy"],
            records=list(d["records"]),
            schema_version=int(d["schema_version"]))

    @classmethod
    def load(cls, path) -> "TraceArtifact":
        return cls.from_dict(json.loads(Path(path).read_text()))


def validate_trace_dict(d: Dict[str, Any]) -> List[str]:
    """Structural schema check; returns a list of problems (empty = ok)."""
    errors: List[str] = []
    if not isinstance(d, dict):
        return ["trace is not a JSON object"]
    if d.get("schema") != TRACE_SCHEMA:
        errors.append(f"schema != {TRACE_SCHEMA!r}")
    if d.get("schema_version") != TRACE_SCHEMA_VERSION:
        errors.append(f"schema_version != {TRACE_SCHEMA_VERSION}")
    for key, typ in (("scenario", dict), ("kind", str), ("strategy", str),
                     ("seed", int), ("rounds", int), ("clients", dict),
                     ("hierarchy", dict), ("records", list)):
        if not isinstance(d.get(key), typ):
            errors.append(f"missing/mistyped field {key!r} "
                          f"(want {typ.__name__})")
    if errors:
        return errors
    for key in ("pspeed", "mdatasize", "memcap"):
        if not isinstance(d["clients"].get(key), list):
            errors.append(f"clients.{key} missing")
    for key in ("depth", "width", "trainers_per_leaf", "n_clients"):
        if not isinstance(d["hierarchy"].get(key), int):
            errors.append(f"hierarchy.{key} missing")
    if len(d["records"]) != d["rounds"]:
        errors.append(f"expected {d['rounds']} records, "
                      f"got {len(d['records'])}")
    for i, rec in enumerate(d["records"]):
        for key in ("round", "placement", "tpd", "train_time",
                    "agg_time", "train", "levels"):
            if key not in rec:
                errors.append(f"records[{i}] missing {key!r}")
        for j, row in enumerate(rec.get("levels", [])):
            for key in ("level", "slots", "hosts", "loads", "n_parts",
                        "delays"):
                if key not in row:
                    errors.append(
                        f"records[{i}].levels[{j}] missing {key!r}")
    return errors


def record_trace(scenario, strategy: str = "pso", *, seed: int = 0,
                 rounds: Optional[int] = None, config=None,
                 verbose: bool = False) -> TraceArtifact:
    """Run one (scenario, strategy, seed) trajectory with recording on
    and return its :class:`TraceArtifact`.

    Drives the ordinary sequential loop (``run_single`` with
    ``EvalConfig(recording='on')`` and an ``on_observation`` hook), so
    the recorded run's trajectory is bit-identical to an unrecorded
    one. Calibration needs a stationary measurement, so scenarios with
    event schedules, fault schedules or client sampling are refused —
    their pools mutate mid-run and the trace's client snapshot would
    lie about the later rounds.
    """
    from repro.experiments.eval_config import EvalConfig
    from repro.experiments.runner import run_single
    from repro.experiments.scenarios import get_scenario

    spec = get_scenario(scenario) if isinstance(scenario, str) else scenario
    rounds = rounds if rounds is not None else spec.rounds
    if spec.events:
        raise ValueError(
            f"scenario {spec.name!r} schedules events — record traces "
            "from stationary (event-free) scenarios")
    if not spec.make_faults(seed).empty:
        raise ValueError(
            f"scenario {spec.name!r} schedules faults — record traces "
            "from fault-free scenarios")
    if getattr(spec, "sampling", "off") != "off":
        raise ValueError(
            f"scenario {spec.name!r} samples cohorts — record traces "
            "from fully-participating scenarios")

    records: List[Dict[str, Any]] = []

    def on_observation(obs) -> None:
        t = obs.timings
        records.append({
            "round": int(obs.round_idx),
            "placement": [int(c) for c in obs.placement],
            "tpd": float(obs.tpd),
            "train_time": float(t.get("train_time", 0.0)),
            "agg_time": float(t.get("agg_time", 0.0)),
            "train": t.get("train", {"clients": [], "times": []}),
            "levels": t.get("levels", []),
        })

    run_single(spec, strategy, seed=seed, rounds=rounds, config=config,
               verbose=verbose, eval_config=EvalConfig(recording="on"),
               on_observation=on_observation)

    # the pool/hierarchy snapshot: stationary by the refusals above, so
    # re-materializing from (spec, seed) reproduces the run's exact pool
    pool = spec.make_pool(seed)
    h = spec.make_hierarchy()
    return TraceArtifact(
        # json round trip: the spec dict may hold tuples, which a
        # save/load cycle would turn into lists — store JSON-native
        # types so to_dict() is stable across round trips
        scenario=json.loads(json.dumps(spec.to_dict())),
        kind=spec.kind, strategy=strategy,
        seed=int(seed), rounds=int(rounds),
        comm_latency=float(spec.comm_latency),
        local_steps=int(spec.local_steps),
        clients={
            "pspeed": [float(x) for x in np.asarray(pool.pspeed)],
            "mdatasize": [float(x) for x in np.asarray(pool.mdatasize)],
            "memcap": [float(x) for x in np.asarray(pool.memcap)],
        },
        hierarchy={
            "depth": int(h.depth), "width": int(h.width),
            "trainers_per_leaf": int(h.trainers_per_leaf),
            "n_clients": int(h.total_clients),
        },
        records=records)
