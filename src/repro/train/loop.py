"""Checkpointed training loop: the production driver around
``make_train_step``.

Features a real trainer needs and nothing it doesn't:
  * jit'd step (sharded or not — the step fn decides),
  * periodic eval on a held-out batch,
  * atomic checkpoints (params + optimizer state + step + RNG-free
    dataset cursor) every ``save_every`` steps,
  * crash-safe resume: ``TrainLoop(...).run()`` continues from the
    newest checkpoint if one exists — byte-identical to an uninterrupted
    run (tested in tests/test_train_loop.py),
  * a metrics log (list of dicts; JSON-serializable).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint.store import latest_step, restore_checkpoint, save_checkpoint
from repro.models.api import Model, make_train_step


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    eval_every: int = 20
    save_every: int = 50
    log_every: int = 10
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3


class TrainLoop:
    """Drives ``(params, opt_state, batch) -> (params, opt_state, metrics)``
    over a ``batch_fn(step) -> batch`` data source."""

    def __init__(self, model: Model, optimizer, batch_fn: Callable,
                 cfg: TrainLoopConfig, *,
                 eval_batch_fn: Optional[Callable] = None, seed: int = 0):
        self.model = model
        self.optimizer = optimizer
        self.batch_fn = batch_fn
        self.eval_batch_fn = eval_batch_fn
        self.cfg = cfg
        self.step_fn = jax.jit(make_train_step(model, optimizer))
        self._eval = jax.jit(lambda p, b: model.loss_fn(p, b)) \
            if eval_batch_fn else None

        self.params = model.init(jax.random.key(seed))
        self.opt_state = optimizer.init(self.params)
        self.start_step = 0
        self.metrics_log: List[Dict[str, Any]] = []

        if cfg.checkpoint_dir and latest_step(cfg.checkpoint_dir) is not None:
            self._resume()

    # ------------------------------------------------------------------
    def _resume(self) -> None:
        like = {"params": self.params, "opt": self.opt_state}
        tree, extra = restore_checkpoint(self.cfg.checkpoint_dir, like)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.start_step = int(extra.get("step", 0))
        self.metrics_log = extra.get("metrics_log", [])

    def _save(self, step: int) -> None:
        if not self.cfg.checkpoint_dir:
            return
        save_checkpoint(
            self.cfg.checkpoint_dir, step,
            {"params": self.params, "opt": self.opt_state},
            extra={"step": step, "metrics_log": self.metrics_log})
        self._prune()

    def _prune(self) -> None:
        d = Path(self.cfg.checkpoint_dir)
        steps = sorted(int(p.name.split("_")[1]) for p in d.iterdir()
                       if p.name.startswith("step_"))
        for s in steps[: -self.cfg.keep_checkpoints]:
            import shutil
            shutil.rmtree(d / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def run(self, verbose: bool = False) -> dict:
        t0 = time.perf_counter()
        for step in range(self.start_step, self.cfg.total_steps):
            batch = self.batch_fn(step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            if (step + 1) % self.cfg.log_every == 0 or \
                    step + 1 == self.cfg.total_steps:
                rec = {"step": step + 1,
                       **{k: float(v) for k, v in metrics.items()}}
                if self._eval and (step + 1) % self.cfg.eval_every == 0:
                    el, em = self._eval(self.params,
                                        self.eval_batch_fn(step))
                    rec["eval_loss"] = float(el)
                self.metrics_log.append(rec)
                if verbose:
                    print(json.dumps(rec))
            if (step + 1) % self.cfg.save_every == 0 or \
                    step + 1 == self.cfg.total_steps:
                self._save(step + 1)
        return {
            "steps": self.cfg.total_steps,
            "wall_s": time.perf_counter() - t0,
            "final": self.metrics_log[-1] if self.metrics_log else {},
            "metrics_log": self.metrics_log,
        }
