"""``python -m repro.analysis`` — run the pass, print findings, exit 1
on any violation (the `analyze` CI job and ``make analyze`` call this)."""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Optional, Sequence

from repro.analysis import engine, rules


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="repo-invariant static analysis (rules RPL000-RPL004)",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="directories/files to scan, relative to --root "
        "(default: src tests)",
    )
    ap.add_argument(
        "--root",
        default=".",
        help="repository root the scan paths are relative to",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, summary in sorted(rules.RULES.items()):
            print(f"{code}  {summary}")
        return 0

    root = Path(args.root).resolve()
    paths = tuple(args.paths) or ("src", "tests")
    contexts = engine.load_tree(root, paths)
    violations = engine.run(contexts, root=root)
    for v in violations:
        print(v.render())
    n_files = len(contexts)
    if violations:
        print(
            f"repro.analysis: {len(violations)} violation(s) "
            f"in {n_files} files",
            file=sys.stderr,
        )
        return 1
    print(f"repro.analysis: {n_files} files clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
