"""RPL001: the machine-readable parity-oracle registry.

Every vectorized/batched public entry point and every Pallas kernel in
the hot packages must appear here as the ``fast`` half of an
:class:`OraclePair`, pointing at its scalar/sequential oracle and at
least one test file exercising both. The check fails when

* a ``batch_*`` / ``batched_*`` / ``*_batched`` public def or a
  function calling ``pl.pallas_call`` lands unregistered (suppressible
  at the def site with a reasoned ``RPL001`` pragma — e.g. a shape
  helper that merely matches the name pattern);
* a registry entry's ``fast`` or ``oracle`` symbol no longer resolves
  (registry rot — deleting ``tpd_ref`` fails the pass);
* a listed test file is missing, or none of them textually mention both
  the fast and oracle base names.

Symbols are AST-resolved from source, never imported — the pass runs in
the lint tier before jax is available.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, List, Optional, Sequence, Tuple

from repro.analysis.engine import FileContext, Violation

REGISTRY_PATH = "src/repro/analysis/parity.py"

# packages whose batch-pattern defs and Pallas kernels must be paired
SCAN_PREFIXES = (
    "src/repro/core/",
    "src/repro/fl/",
    "src/repro/kernels/",
    "src/repro/experiments/",
    "src/repro/online/",
    "src/repro/faults/",
    "src/repro/calibration/",
)
_BATCH_NAME = re.compile(r"^batch(ed)?_|_batched$")


@dataclass(frozen=True)
class OraclePair:
    """``fast`` and ``oracle`` are ``module:qualname`` strings."""

    fast: str
    oracle: str
    tests: Tuple[str, ...]


REGISTRY: Tuple[OraclePair, ...] = (
    # --- swarm optimizer: vectorized run vs. the sequential reference ---
    OraclePair(
        fast="repro.core.pso:FlagSwapPSO.run",
        oracle="repro.core.pso:FlagSwapPSO._run_reference",
        tests=("tests/test_scale_parity.py",),
    ),
    OraclePair(
        fast="repro.core.pso:FlagSwapPSO._dedup_fix",
        oracle="repro.core.pso:FlagSwapPSO._dedup_ints",
        tests=("tests/test_scale_parity.py",),
    ),
    # --- cost model: batched/pooled evaluators vs. the scalar eq. 6-7 ---
    OraclePair(
        fast="repro.core.cost_model:CostModel.batch_tpd",
        oracle="repro.core.cost_model:CostModel.tpd",
        tests=("tests/test_cost_model.py", "tests/test_scale_parity.py"),
    ),
    OraclePair(
        fast="repro.core.cost_model:CostModel.tpd_fast",
        oracle="repro.core.cost_model:CostModel.tpd",
        tests=("tests/test_scale_parity.py",),
    ),
    OraclePair(
        fast="repro.core.cost_model:CostModel.batch_fitness",
        oracle="repro.core.cost_model:CostModel.fitness",
        tests=("tests/test_scale_parity.py",),
    ),
    OraclePair(
        fast="repro.core.cost_model:PooledTPDEvaluator.tpds",
        oracle="repro.core.cost_model:CostModel.tpd_fast",
        tests=("tests/test_scale_parity.py",),
    ),
    OraclePair(
        fast="repro.core.cost_model:PooledTPDEvaluator.tpds_sharded",
        oracle="repro.core.cost_model:CostModel.tpd_fast",
        tests=("tests/test_scale_parity.py",),
    ),
    OraclePair(
        fast="repro.core.cost_model:TwoTierCostModel.cross_pod_edges",
        oracle="repro.core.cost_model:TwoTierCostModel._cross_pod_edges_ref",
        tests=("tests/test_scale_parity.py",),
    ),
    # --- aggregation: segment-summed tree fedavg vs. sequential walk ---
    OraclePair(
        fast="repro.fl.aggregation:batched_hierarchical_fedavg",
        oracle="repro.fl.aggregation:hierarchical_fedavg",
        tests=("tests/test_round_engine.py",),
    ),
    # --- experiment runner: lockstep batched sweep vs. one-run loop ---
    OraclePair(
        fast="repro.experiments.runner:run_batched",
        oracle="repro.experiments.runner:run_single",
        tests=("tests/test_analysis_sanitize.py",),
    ),
    # --- online track: staleness-weighted async merge vs. scalar loop ---
    OraclePair(
        fast="repro.online.async_fedavg:staleness_weights",
        oracle="repro.online.async_fedavg:_staleness_weights_ref",
        tests=("tests/test_online.py",),
    ),
    OraclePair(
        fast="repro.online.async_fedavg:async_merge_batched",
        oracle="repro.online.async_fedavg:_async_merge_ref",
        tests=("tests/test_online.py",),
    ),
    # --- calibration: vectorized cluster-delay surrogate vs. scalar ---
    OraclePair(
        fast="repro.calibration.fit:batch_predict_cluster_delay",
        oracle="repro.calibration.fit:_predict_cluster_delay_ref",
        tests=("tests/test_calibration.py",),
    ),
    # --- fault track: quorum-gated participation-damped merge ---
    OraclePair(
        fast="repro.faults.tolerance:quorum_merge_batched",
        oracle="repro.faults.tolerance:_quorum_merge_ref",
        tests=("tests/test_faults.py",),
    ),
    # --- Pallas kernels: each entry point vs. its jnp oracle ---
    OraclePair(
        fast="repro.kernels.tpd:batch_tpd_pallas",
        oracle="repro.kernels.ref:tpd_ref",
        tests=("tests/test_scale_parity.py",),
    ),
    OraclePair(
        fast="repro.kernels.fedavg:fedavg_batched_pallas",
        oracle="repro.kernels.ref:fedavg_ref",
        tests=("tests/test_kernels.py",),
    ),
    OraclePair(
        fast="repro.kernels.fedavg:fedavg_pallas",
        oracle="repro.kernels.ref:fedavg_ref",
        tests=("tests/test_kernels.py",),
    ),
    OraclePair(
        fast="repro.kernels.flash_attention:flash_attention_pallas",
        oracle="repro.kernels.ref:flash_attention_ref",
        tests=("tests/test_kernels.py",),
    ),
    OraclePair(
        fast="repro.kernels.rglru:rglru_scan_pallas",
        oracle="repro.kernels.ref:rglru_scan_ref",
        tests=("tests/test_kernels.py",),
    ),
    OraclePair(
        fast="repro.kernels.fused_adamw:fused_adamw_pallas",
        oracle="repro.kernels.ref:fused_adamw_ref",
        tests=("tests/test_kernels.py",),
    ),
)


def module_rel_path(module: str) -> str:
    return "src/" + module.replace(".", "/") + ".py"


def resolve_symbol(
    contexts_by_rel: dict, symbol: str
) -> Optional[ast.FunctionDef]:
    """AST-resolve ``module:Qual.name`` against the scanned tree."""
    module, _, qualname = symbol.partition(":")
    ctx = contexts_by_rel.get(module_rel_path(module))
    if ctx is None:
        return None
    node: ast.AST = ctx.tree
    for part in qualname.split("."):
        for child in ast.iter_child_nodes(node):
            if (
                isinstance(
                    child, (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef)
                )
                and child.name == part
            ):
                node = child
                break
        else:
            return None
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return node
    return None


def _calls_pallas(fn: ast.AST, ctx: FileContext) -> bool:
    for sub in ast.walk(fn):
        if (
            isinstance(sub, ast.Call)
            and isinstance(sub.func, ast.Attribute)
            and sub.func.attr == "pallas_call"
            and ctx.enclosing_function(sub) is fn
        ):
            return True
    return False


def _iter_defs(
    ctx: FileContext,
) -> Iterator[Tuple[str, ast.FunctionDef]]:
    """(qualname, node) for module-level defs and class methods."""
    for child in ast.iter_child_nodes(ctx.tree):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield child.name, child
        elif isinstance(child, ast.ClassDef):
            for sub in ast.iter_child_nodes(child):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield f"{child.name}.{sub.name}", sub


def check(
    contexts: Sequence[FileContext],
    registry: Optional[Sequence[OraclePair]] = None,
    root: Optional[Path] = None,
) -> List[Violation]:
    by_rel = {ctx.rel: ctx for ctx in contexts}
    if registry is None:
        # default registry only binds when the scan covers this repo's
        # tree (partial scans / foreign roots can't resolve its symbols)
        registry = REGISTRY if REGISTRY_PATH in by_rel else ()
    out: List[Violation] = []

    registered = set()
    for pair in registry:
        module, _, qualname = pair.fast.partition(":")
        registered.add((module_rel_path(module), qualname))
        for role, symbol in (("fast", pair.fast), ("oracle", pair.oracle)):
            if resolve_symbol(by_rel, symbol) is None:
                mod_rel = module_rel_path(symbol.partition(":")[0])
                out.append(
                    Violation(
                        REGISTRY_PATH,
                        1,
                        "RPL001",
                        f"registry {role} symbol {symbol!r} does not resolve "
                        f"in {mod_rel} — stale entry or deleted oracle",
                    )
                )
        fast_base = pair.fast.partition(":")[2].rpartition(".")[2]
        oracle_base = pair.oracle.partition(":")[2].rpartition(".")[2]
        mentioned = False
        for test in pair.tests:
            tctx = by_rel.get(test)
            if tctx is not None:
                text: Optional[str] = tctx.source
            elif root is not None and (root / test).is_file():
                text = (root / test).read_text()
            else:
                text = None
            if text is None:
                out.append(
                    Violation(
                        REGISTRY_PATH,
                        1,
                        "RPL001",
                        f"registry entry {pair.fast!r} lists missing test "
                        f"file {test!r}",
                    )
                )
                continue
            if fast_base in text and oracle_base in text:
                mentioned = True
        if not mentioned:
            out.append(
                Violation(
                    REGISTRY_PATH,
                    1,
                    "RPL001",
                    f"no listed test exercises both {fast_base!r} and its "
                    f"oracle {oracle_base!r} for entry {pair.fast!r}",
                )
            )

    for ctx in contexts:
        if not ctx.rel.startswith(SCAN_PREFIXES):
            continue
        for qualname, fn in _iter_defs(ctx):
            base = qualname.rpartition(".")[2]
            is_batch = not base.startswith("_") and _BATCH_NAME.search(base)
            if not is_batch and not _calls_pallas(fn, ctx):
                continue
            if (ctx.rel, qualname) in registered:
                continue
            out.append(
                Violation(
                    ctx.rel,
                    fn.lineno,
                    "RPL001",
                    f"{qualname} looks like a vectorized/Pallas entry point "
                    "but has no parity-oracle registry entry "
                    "(analysis/parity.py) — register it with its scalar "
                    "oracle and a test covering both",
                )
            )
    return out
