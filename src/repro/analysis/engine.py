"""Analysis engine: file discovery, pragma parsing, rule dispatch.

The engine is deliberately stdlib-only (``ast`` + ``re``): the pass must
run in the lint tier of CI before any heavyweight dependency is
imported, and it must be able to parse files that would fail to import
(that is the point of a lint).

Suppression pragma grammar (trailing on the flagged line, or alone on
the line directly above it)::

    # repro-lint: disable=RPL002 (seed restored from checkpoint state)
    # repro-lint: disable=RPL001,RPL004 (reason covering both)

The parenthesised reason is mandatory: a pragma without one never
suppresses anything and is itself reported as RPL000, so ``make
analyze`` exiting 0 guarantees every suppression in the tree carries a
written justification.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*disable=(?P<codes>[A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)"
    r"(?:\s*\((?P<reason>[^)]+)\))?"
)

# directories never scanned: fixtures are deliberate rule violations
EXCLUDED_PARTS = frozenset({"analysis_fixtures", "__pycache__"})


@dataclass(frozen=True)
class Violation:
    """One rule finding, keyed the way CI and editors expect."""

    path: str  # repo-relative posix path
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


@dataclass(frozen=True)
class Pragma:
    line: int
    codes: Tuple[str, ...]
    reason: str  # "" when the author omitted the mandatory reason


@dataclass
class FileContext:
    """One parsed file plus everything rules need to inspect it."""

    path: Path  # absolute
    rel: str  # repo-relative posix path ("src/repro/core/pso.py")
    source: str
    tree: ast.Module
    pragmas: List[Pragma]
    parents: Dict[ast.AST, ast.AST]

    def suppressed(self, line: int, code: str) -> bool:
        """True when a well-formed pragma covers ``code`` at ``line``.

        A pragma suppresses its own line (trailing comment) and the line
        below it (comment-above style). Reasonless pragmas suppress
        nothing — they only produce RPL000.
        """
        for p in self.pragmas:
            if code in p.codes and p.reason and line in (p.line, p.line + 1):
                return True
        return False

    def enclosing_function(
        self, node: ast.AST
    ) -> Optional[ast.FunctionDef]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.random.key`` -> "jax.random.key"; None for non-name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _parse_pragmas(source: str) -> List[Pragma]:
    pragmas = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = PRAGMA_RE.search(text)
        if m is None:
            continue
        codes = tuple(c.strip() for c in m.group("codes").split(","))
        pragmas.append(
            Pragma(line=lineno, codes=codes, reason=(m.group("reason") or "").strip())
        )
    return pragmas


def _build_parents(tree: ast.Module) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def load_context(path: Path, root: Path, rel: Optional[str] = None) -> FileContext:
    """Parse one file. ``rel`` overrides the repo-relative path — tests
    use this to scan fixture snippets as if they lived under ``src/``."""
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    return FileContext(
        path=path,
        rel=rel if rel is not None else path.resolve().relative_to(root).as_posix(),
        source=source,
        tree=tree,
        pragmas=_parse_pragmas(source),
        parents=_build_parents(tree),
    )


def iter_python_files(root: Path, paths: Sequence[str]) -> Iterable[Path]:
    for entry in paths:
        base = root / entry
        if base.is_file() and base.suffix == ".py":
            yield base
            continue
        for p in sorted(base.rglob("*.py")):
            if EXCLUDED_PARTS.isdisjoint(p.parts):
                yield p


def load_tree(root: Path, paths: Sequence[str] = ("src", "tests")) -> List[FileContext]:
    return [load_context(p, root) for p in iter_python_files(root, paths)]


def _check_pragmas(ctx: FileContext, known_codes: Iterable[str]) -> List[Violation]:
    """RPL000: every pragma must carry a reason and reference real rules.

    Not suppressible — a reasonless pragma suppressing its own report
    would defeat the mandatory-reason contract.
    """
    known = set(known_codes)
    out = []
    for p in ctx.pragmas:
        if not p.reason:
            out.append(
                Violation(
                    ctx.rel,
                    p.line,
                    "RPL000",
                    "suppression pragma is missing its mandatory "
                    "(reason) — say why the finding is sound",
                )
            )
        unknown = [c for c in p.codes if c not in known]
        if unknown:
            out.append(
                Violation(
                    ctx.rel,
                    p.line,
                    "RPL000",
                    f"pragma references unknown rule(s): {', '.join(unknown)}",
                )
            )
    return out


def run(
    contexts: Sequence[FileContext],
    root: Optional[Path] = None,
    registry: Optional[Sequence] = None,
) -> List[Violation]:
    """Run every rule over ``contexts`` and return unsuppressed findings."""
    # imported here so `engine` stays importable from rules/parity
    from repro.analysis import parity, rules

    violations: List[Violation] = []
    for ctx in contexts:
        violations.extend(_check_pragmas(ctx, rules.RULES))
        for check in rules.PER_FILE_CHECKS:
            for v in check(ctx):
                if not ctx.suppressed(v.line, v.code):
                    violations.append(v)
    by_rel = {ctx.rel: ctx for ctx in contexts}
    for v in parity.check(contexts, registry=registry, root=root):
        ctx = by_rel.get(v.path)
        if ctx is None or not ctx.suppressed(v.line, v.code):
            violations.append(v)
    return sorted(violations, key=lambda v: (v.path, v.line, v.code))
