"""repro.analysis: the repo-invariant static-analysis pass.

The paper's reproduction rests on two mechanical contracts: every
vectorized/batched hot path is pinned bit-identical to a scalar oracle,
and every stochastic stream derives from an explicit ``(seed, stream)``
tuple. This package enforces those contracts (plus the jit/cache-key and
determinism-source hygiene they depend on) as an AST lint over ``src/``
and ``tests/`` — run it with ``make analyze`` or
``PYTHONPATH=src python -m repro.analysis``.

Rule catalog (see :mod:`repro.analysis.rules` for the checkers and
:mod:`repro.analysis.parity` for the oracle registry):

========  ==============================================================
RPL000    malformed suppression pragma (missing reason / unknown code)
RPL001    vectorized or Pallas entry point without a registered scalar
          oracle + parity test (``analysis/parity.py`` registry)
RPL002    rng constructed from literal / ``hash()`` seeds instead of a
          named stream constant or seed parameter
RPL003    ``jax.jit`` without explicit ``static_argnames`` in ``core/``
          or ``fl/``; version-token cache keys capturing the mutable
          object in a closure
RPL004    nondeterminism sources: wall-clock reads, unordered set/dict
          iteration feeding arrays, salted string ``hash()``
========  ==============================================================

Violations are suppressed inline with a written reason::

    something_flagged()  # repro-lint: disable=RPL004 (timing display only)

A pragma without a parenthesised reason is itself an RPL000 violation,
so every suppression in the tree documents why it is sound.
"""
from repro.analysis.engine import FileContext, Pragma, Violation, load_context, load_tree, run
from repro.analysis.parity import REGISTRY, OraclePair
from repro.analysis.rules import RULES
from repro.analysis.sanitize import (
    DeterminismError,
    artifact_hash,
    assert_deterministic,
    determinism_guard,
)

__all__ = [
    "FileContext",
    "Pragma",
    "Violation",
    "load_context",
    "load_tree",
    "run",
    "REGISTRY",
    "OraclePair",
    "RULES",
    "DeterminismError",
    "artifact_hash",
    "assert_deterministic",
    "determinism_guard",
]
