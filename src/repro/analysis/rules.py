"""Per-file AST rules RPL002-RPL004 (RPL000 lives in the engine, RPL001
in :mod:`repro.analysis.parity` — it needs the cross-file view).

Each checker takes a :class:`~repro.analysis.engine.FileContext` and
returns raw :class:`~repro.analysis.engine.Violation`\\ s; the engine
applies pragma suppression. Scoping is by repo-relative path prefix so
tests can replay the rules against fixture snippets under a synthetic
``src/`` path.
"""
from __future__ import annotations

import ast
from typing import Callable, Dict, List, Sequence

from repro.analysis.engine import FileContext, Violation, dotted_name

RULES: Dict[str, str] = {
    "RPL000": "suppression pragma must carry a (reason) and name real rules",
    "RPL001": "vectorized/batched or Pallas entry point must be registered "
    "in the parity-oracle registry with a test covering both paths",
    "RPL002": "rng streams in src/ must derive from a named stream constant "
    "or a seed parameter — no literal seeds, no hash()-derived seeds",
    "RPL003": "jax.jit in core//fl/ must declare static_argnames; "
    "version-token cache keys must not close over the mutable object",
    "RPL004": "no wall-clock reads, unordered set/dict iteration into "
    "arrays, or salted string hash() outside the bench allowlist",
}

# --------------------------------------------------------------------------
# RPL002: rng-stream discipline
# --------------------------------------------------------------------------

_RNG_CONSTRUCTORS = {
    "default_rng",
    "np.random.default_rng",
    "numpy.random.default_rng",
    "SeedSequence",
    "np.random.SeedSequence",
    "numpy.random.SeedSequence",
    "PRNGKey",
    "random.PRNGKey",
    "jax.random.PRNGKey",
    "jax.random.key",
}


def _contains_numeric_literal(node: ast.AST) -> bool:
    return any(
        isinstance(sub, ast.Constant) and isinstance(sub.value, (int, float))
        and not isinstance(sub.value, bool)
        for sub in ast.walk(node)
    )


def _contains_hash_call(node: ast.AST) -> bool:
    return any(
        isinstance(sub, ast.Call)
        and isinstance(sub.func, ast.Name)
        and sub.func.id == "hash"
        for sub in ast.walk(node)
    )


def _restores_bit_generator_state(fn: ast.AST) -> bool:
    """True when the enclosing function reassigns ``<rng>.bit_generator
    .state`` — the checkpoint-restore idiom where a fresh ``default_rng()``
    is immediately overwritten with saved state."""
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign):
            for tgt in sub.targets:
                if (
                    isinstance(tgt, ast.Attribute)
                    and tgt.attr == "state"
                    and isinstance(tgt.value, ast.Attribute)
                    and tgt.value.attr == "bit_generator"
                ):
                    return True
    return False


def check_rng_streams(ctx: FileContext) -> List[Violation]:
    if not ctx.rel.startswith("src/"):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        seed_exprs: List[ast.AST] = []
        if name in _RNG_CONSTRUCTORS:
            if not node.args and not node.keywords:
                fn = ctx.enclosing_function(node)
                if fn is None or not _restores_bit_generator_state(fn):
                    out.append(
                        Violation(
                            ctx.rel,
                            node.lineno,
                            "RPL002",
                            f"{name}() with no seed is OS-entropy "
                            "nondeterminism; pass (seed, STREAM) — only the "
                            "checkpoint bit_generator.state restore idiom "
                            "is exempt",
                        )
                    )
                continue
            seed_exprs = [*node.args, *[k.value for k in node.keywords]]
        else:
            # seed= keyword anywhere in src/ is a stream boundary too
            seed_exprs = [k.value for k in node.keywords if k.arg == "seed"]
        for expr in seed_exprs:
            if _contains_hash_call(expr):
                out.append(
                    Violation(
                        ctx.rel,
                        node.lineno,
                        "RPL002",
                        "hash()-derived seed (salted for str, opaque for "
                        "ints) — derive with np.random.SeedSequence over "
                        "named stream parts",
                    )
                )
            elif _contains_numeric_literal(expr):
                out.append(
                    Violation(
                        ctx.rel,
                        node.lineno,
                        "RPL002",
                        "literal seed component — name it as a module-level "
                        "_*_STREAM constant or take it as a parameter",
                    )
                )
    return out


# --------------------------------------------------------------------------
# RPL003: jit/cache-key hygiene
# --------------------------------------------------------------------------

_JIT_SCOPES = ("src/repro/core/", "src/repro/fl/")
_VERSION_ATTRS = {"version", "topology_version"}


def _is_jax_jit(node: ast.AST) -> bool:
    return dotted_name(node) in ("jax.jit", "jit")


def _root_name(node: ast.Attribute) -> str | None:
    cur: ast.AST = node
    while isinstance(cur, ast.Attribute):
        cur = cur.value
    return cur.id if isinstance(cur, ast.Name) else None


def check_jit_hygiene(ctx: FileContext) -> List[Violation]:
    if not ctx.rel.startswith(_JIT_SCOPES):
        return []
    out = []
    # jax.jit nodes configured through functools.partial(jax.jit, static_...)
    configured = set()
    for node in ast.walk(ctx.tree):
        if (
            isinstance(node, ast.Call)
            and dotted_name(node.func) in ("functools.partial", "partial")
            and node.args
            and _is_jax_jit(node.args[0])
            and any(k.arg and k.arg.startswith("static_") for k in node.keywords)
        ):
            configured.add(id(node.args[0]))
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and _is_jax_jit(node.func)):
            continue
        if id(node.func) in configured:
            continue
        if not any(k.arg and k.arg.startswith("static_") for k in node.keywords):
            out.append(
                Violation(
                    ctx.rel,
                    node.lineno,
                    "RPL003",
                    "jax.jit without explicit static_argnames — declare the "
                    "static surface (static_argnames=() when there is none) "
                    "so cache-key behavior is reviewable",
                )
            )
    # version-token reads must not coexist with closures over the object
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        version_roots = set()
        for sub in ast.walk(fn):
            if (
                isinstance(sub, ast.Attribute)
                and sub.attr in _VERSION_ATTRS
                and ctx.enclosing_function(sub) is fn
            ):
                root = _root_name(sub)
                if root is not None:
                    version_roots.add(root)
        if not version_roots:
            continue
        for sub in ast.walk(fn):
            if sub is fn or not isinstance(
                sub, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
            ):
                continue
            captured = version_roots & {
                n.id
                for n in ast.walk(sub)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
            }
            if captured:
                out.append(
                    Violation(
                        ctx.rel,
                        sub.lineno,
                        "RPL003",
                        f"closure captures mutable object(s) {sorted(captured)} "
                        f"whose version token {fn.name} reads for a cache key — "
                        "bake a snapshot into locals instead",
                    )
                )
    return out


# --------------------------------------------------------------------------
# RPL004: determinism sources
# --------------------------------------------------------------------------

_WALL_CLOCK = {
    "time.time",
    "time.time_ns",
    "datetime.now",
    "datetime.utcnow",
    "datetime.today",
    "date.today",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.date.today",
}
_ARRAY_CTORS = {
    "np.array",
    "np.asarray",
    "np.stack",
    "np.fromiter",
    "numpy.array",
    "numpy.asarray",
    "jnp.array",
    "jnp.asarray",
    "jnp.stack",
}


def _is_unordered_iteration(node: ast.AST) -> bool:
    if isinstance(node, ast.SetComp):
        return True
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func)
        if fname == "set":
            return True
        if isinstance(node.func, ast.Attribute) and node.func.attr in (
            "keys",
            "values",
        ):
            return True
    if isinstance(node, (ast.GeneratorExp, ast.ListComp)):
        return any(_is_unordered_iteration(g.iter) for g in node.generators)
    return False


def check_determinism_sources(ctx: FileContext) -> List[Violation]:
    if not ctx.rel.startswith(("src/", "tests/")):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name in _WALL_CLOCK:
            out.append(
                Violation(
                    ctx.rel,
                    node.lineno,
                    "RPL004",
                    f"{name}() is a wall-clock read — use time.perf_counter "
                    "for durations; benchmarks/ is the timing allowlist",
                )
            )
        elif name in _ARRAY_CTORS and node.args and _is_unordered_iteration(
            node.args[0]
        ):
            out.append(
                Violation(
                    ctx.rel,
                    node.lineno,
                    "RPL004",
                    f"{name} over unordered set/dict iteration — element "
                    "order is insertion/hash dependent; sorted(...) first",
                )
            )
        elif (
            ctx.rel.startswith("src/")
            and name == "hash"
            and any(
                isinstance(sub, ast.Constant) and isinstance(sub.value, str)
                for a in node.args
                for sub in ast.walk(a)
            )
        ):
            out.append(
                Violation(
                    ctx.rel,
                    node.lineno,
                    "RPL004",
                    "hash() over a str is salted per process "
                    "(PYTHONHASHSEED) — any value derived from it differs "
                    "across runs",
                )
            )
    return out


PER_FILE_CHECKS: Sequence[Callable[[FileContext], List[Violation]]] = (
    check_rng_streams,
    check_jit_hygiene,
    check_determinism_sources,
)
