"""Runtime determinism sanitizer: run twice, hash the artifacts.

The static rules keep nondeterminism *sources* out of the tree; this
module turns the complementary runtime claim — "this round/sweep is
bit-identical when repeated" — into an executable check that new
environments inherit for free:

    from repro.analysis.sanitize import assert_deterministic

    obs = assert_deterministic(lambda: env.step(0, placement))

or, batching several checks through one report::

    with determinism_guard() as guard:
        guard.check("round0", lambda: env.step(0, placement))
        guard.check("pso", lambda: pso_run())

``artifact_hash`` canonicalizes nested dicts (sorted keys), sequences,
dataclasses, scalars, and anything ``np.asarray`` understands (numpy
and jax arrays included) into one sha256, so two results collide iff
every array byte and every scalar matches.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, List, Optional, Tuple, TypeVar

import numpy as np

T = TypeVar("T")


class DeterminismError(AssertionError):
    """Raised when repeated runs of a factory disagree bit-for-bit."""


def _update(h: "hashlib._Hash", obj: Any) -> None:
    if obj is None or isinstance(obj, (bool, int, str, bytes)):
        h.update(b"s")
        h.update(repr(obj).encode())
    elif isinstance(obj, float):
        # through float64 bytes: hashes -0.0 != 0.0 and nan == nan,
        # which is exactly the bit-identity contract
        h.update(b"f")
        h.update(np.float64(obj).tobytes())
    elif isinstance(obj, dict):
        h.update(b"d")
        for key in sorted(obj, key=repr):
            _update(h, key)
            _update(h, obj[key])
    elif isinstance(obj, (list, tuple)):
        h.update(b"l")
        h.update(str(len(obj)).encode())
        for item in obj:
            _update(h, item)
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        h.update(b"c")
        h.update(type(obj).__name__.encode())
        for f in dataclasses.fields(obj):
            _update(h, f.name)
            _update(h, getattr(obj, f.name))
    else:
        arr = np.asarray(obj)  # covers np/jax arrays and array scalars
        h.update(b"a")
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())


def artifact_hash(obj: Any) -> str:
    """sha256 over the canonicalized artifact tree."""
    h = hashlib.sha256()
    _update(h, obj)
    return h.hexdigest()


def assert_deterministic(
    factory: Callable[[], T], runs: int = 2, label: str = ""
) -> T:
    """Call ``factory`` ``runs`` times; raise :class:`DeterminismError`
    unless every result hashes identically. Returns the first result so
    parity tests can keep asserting on it."""
    first = factory()
    want = artifact_hash(first)
    for i in range(1, runs):
        got = artifact_hash(factory())
        if got != want:
            raise DeterminismError(
                f"{label or 'factory'}: run {i} hashed {got[:16]}… but "
                f"run 0 hashed {want[:16]}… — a nondeterminism source "
                "leaked into this path"
            )
    return first


class determinism_guard:
    """Context manager collecting several :func:`assert_deterministic`
    checks into one failure report at ``__exit__``."""

    def __init__(self, runs: int = 2):
        self.runs = runs
        self.failures: List[Tuple[str, str]] = []

    def __enter__(self) -> "determinism_guard":
        return self

    def check(
        self, label: str, factory: Callable[[], T], runs: Optional[int] = None
    ) -> Optional[T]:
        try:
            return assert_deterministic(
                factory, runs=self.runs if runs is None else runs, label=label
            )
        except DeterminismError as e:
            self.failures.append((label, str(e)))
            return None

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and self.failures:
            report = "; ".join(msg for _, msg in self.failures)
            raise DeterminismError(
                f"{len(self.failures)} determinism check(s) failed: {report}"
            )
