"""Fault tolerance: retry policy + quorum-damped degraded merges.

Two mechanisms sit on top of the fault schedule:

* :class:`RetryPolicy` — a dropped update is re-sent after a bounded
  deterministic exponential backoff in VIRTUAL time
  (``base * mult**attempt``); after ``max_retries`` exhausted attempts
  the update is permanently lost and the client re-enters the next
  dispatch cohort.
* :func:`quorum_merge_batched` — the graceful-degradation server rule.
  When a flush carries fewer updates than the quorum
  (:func:`quorum_count` over the LIVE population) the merge is refused
  outright (the model holds); when it proceeds under partial
  participation the staleness weights are renormalized over the
  arrivals exactly as in
  :func:`~repro.online.async_fedavg.async_merge_batched` but the server
  mixing rate is damped by the arrived fraction::

      eta_eff = eta * min(1, arrived_frac)
      global <- (1 - eta_eff) * global + eta_eff * Σ_i w~_i * update_i

  so a 30%-participation degraded flush moves the model 30% as far as
  a full one — a missing client dampens the step instead of silently
  inflating the survivors' influence. ``arrived_frac >= 1`` recovers
  ``async_merge_batched`` bit for bit (the zero-fault parity pin).
  Scalar oracle: :func:`_quorum_merge_ref` (registered parity pair).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp

from repro.online.async_fedavg import (
    _staleness_weights_ref,
    staleness_weights,
)


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded deterministic exponential backoff in virtual time."""
    max_retries: int = 0
    backoff_base: float = 0.25
    backoff_mult: float = 2.0

    @property
    def enabled(self) -> bool:
        return self.max_retries > 0

    def delay(self, attempt: int) -> float:
        """Virtual-time wait before re-delivery attempt ``attempt``
        (0-based)."""
        if attempt < 0:
            raise ValueError(f"negative retry attempt {attempt}")
        return float(self.backoff_base) * float(self.backoff_mult) ** attempt


def quorum_count(live_clients: int, quorum_frac: float) -> int:
    """Merged updates needed for a flush to commit: ceil(frac * live),
    at least 1. ``quorum_frac == 0`` disables the gate."""
    if live_clients <= 0:
        raise ValueError(f"live client count must be positive: "
                         f"{live_clients}")
    if quorum_frac <= 0.0:
        return 1
    return max(1, int(math.ceil(float(quorum_frac) * live_clients)))


def quorum_merge_batched(global_params, stacked_updates, base_weights,
                         staleness, alpha: float, eta: float,
                         arrived_frac: float):
    """Degraded-participation server merge over a stacked flush cohort.

    Identical to :func:`~repro.online.async_fedavg.async_merge_batched`
    except the server mixing rate is damped by the fraction of the
    population that actually arrived: ``eta_eff = eta * min(1,
    arrived_frac)``. Scalar oracle: :func:`_quorum_merge_ref`
    (registered parity pair; equality up to float summation order).
    """
    if arrived_frac <= 0.0:
        raise ValueError(f"arrived_frac must be positive: {arrived_frac}")
    w = jnp.asarray(staleness_weights(base_weights, staleness, alpha))
    eta_eff = float(eta) * min(1.0, float(arrived_frac))

    def merge_leaf(g, u):
        avg = jnp.tensordot(w.astype(u.dtype), u, axes=(0, 0))
        return (1.0 - eta_eff) * g + eta_eff * avg

    return jax.tree.map(merge_leaf, global_params, stacked_updates)


def _quorum_merge_ref(global_params, updates: List, base_weights,
                      staleness, alpha: float, eta: float,
                      arrived_frac: float):
    """Scalar reference: per-update accumulation, one tree at a time."""
    w = _staleness_weights_ref(base_weights, staleness, alpha)
    eta_eff = float(eta) * min(1.0, float(arrived_frac))
    acc = jax.tree.map(jnp.zeros_like, global_params)
    for wi, u in zip(w, updates, strict=True):
        acc = jax.tree.map(lambda a, x, wi=wi: a + wi * x, acc, u)
    return jax.tree.map(
        lambda g, a: (1.0 - eta_eff) * g + eta_eff * a,
        global_params, acc)


__all__ = [
    "RetryPolicy",
    "quorum_count",
    "quorum_merge_batched",
]
