"""Deterministic fault injection + tolerance for the FL tracks.

``repro.faults.schedule`` — the seeded, replayable fault vocabulary
(crashes, drops, link degradation, aggregator failures, partitions)
and :class:`FaultSchedule`/:class:`FaultProfile` generation.

``repro.faults.tolerance`` — what the tracks do about it:
:class:`RetryPolicy` (bounded virtual-time exponential backoff) and
the quorum-gated, participation-damped merge
(:func:`quorum_merge_batched`, parity-pinned against
``_quorum_merge_ref``).
"""
from repro.faults.schedule import (
    AggregatorFailure,
    ClientCrash,
    ClientRecover,
    FaultAt,
    FaultEvent,
    FaultProfile,
    FaultSchedule,
    LinkDegrade,
    NetworkPartition,
    UpdateDrop,
    fault_from_dict,
)
from repro.faults.tolerance import (
    RetryPolicy,
    quorum_count,
    quorum_merge_batched,
)

__all__ = [
    "AggregatorFailure",
    "ClientCrash",
    "ClientRecover",
    "FaultAt",
    "FaultEvent",
    "FaultProfile",
    "FaultSchedule",
    "LinkDegrade",
    "NetworkPartition",
    "RetryPolicy",
    "UpdateDrop",
    "fault_from_dict",
    "quorum_count",
    "quorum_merge_batched",
]
