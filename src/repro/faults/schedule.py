"""Deterministic fault schedules for the online and emulated tracks.

A :class:`FaultSchedule` is a frozen, seed-derivable list of fault
events pinned to round indices. Faults inject through the SAME
machinery the tracks already run on — the online track wraps each fault
in a :class:`FaultAt` clock event scheduled at ``t_round + offset`` on
the :class:`~repro.online.clock.VirtualClock`, the emulated track
applies the round's faults at step start — so every faulty run is
bit-replayable with no wall-clock anywhere.

Semantics shared by both tracks (durations are measured in ROUNDS and
expire at round boundaries, which is what lets one schedule mean the
same thing under event-driven and lockstep execution):

* ``ClientCrash(client, at_round, down_rounds)`` — the client goes
  down; its undelivered in-flight update is voided. ``down_rounds == 0``
  means "until an explicit :class:`ClientRecover`"; ``> 0`` auto-revives
  at the start of round ``at_round + down_rounds``.
* ``ClientRecover(client, at_round)`` — explicit revival.
* ``UpdateDrop(client, at_round)`` — the client's pending update is
  lost in transit once; the retry policy may re-send it (bounded
  exponential backoff in virtual time).
* ``LinkDegrade(client, at_round, factor, for_rounds)`` — the client's
  delivery latency is multiplied by ``factor`` for dispatches during
  the window.
* ``AggregatorFailure(slot, at_round, down_rounds)`` — the client
  HOSTING ``slot`` at fire time crashes; the slot fails over to a live
  unplaced client and in-flight buffer contents re-home under the new
  host.
* ``NetworkPartition(clients, at_round, for_rounds)`` — the named
  clients are unreachable for the window: they are not dispatched, and
  updates already in flight are held and re-injected when the
  partition heals.

``offset`` (online track only; the emulated track is round-granular
and ignores it) delays the fault's injection into the event queue by
that much virtual time past the round's dispatch instant.

RPL002: schedule generation draws from the dedicated
``(seed, _FAULT_STREAM)`` stream only.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field, fields
from typing import Dict, Optional, Tuple

import numpy as np

# rng stream tag for fault-schedule generation: faults drawn for round
# r are independent of every training/event/arrival stream in the run
_FAULT_STREAM = 0xFA175


@dataclass(frozen=True)
class FaultEvent:
    """Base: one fault pinned to a round (and, online, a virtual-time
    offset past that round's dispatch)."""
    at_round: int = 0
    offset: float = 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d["fault"] = type(self).__name__
        return d


@dataclass(frozen=True)
class ClientCrash(FaultEvent):
    client: int = 0
    down_rounds: int = 0    # 0 = until an explicit ClientRecover


@dataclass(frozen=True)
class ClientRecover(FaultEvent):
    client: int = 0


@dataclass(frozen=True)
class UpdateDrop(FaultEvent):
    client: int = 0


@dataclass(frozen=True)
class LinkDegrade(FaultEvent):
    client: int = 0
    factor: float = 3.0
    for_rounds: int = 2


@dataclass(frozen=True)
class AggregatorFailure(FaultEvent):
    slot: int = 0
    down_rounds: int = 1


@dataclass(frozen=True)
class NetworkPartition(FaultEvent):
    clients: Tuple[int, ...] = ()
    for_rounds: int = 1


_FAULT_TYPES: Dict[str, type] = {
    cls.__name__: cls
    for cls in (ClientCrash, ClientRecover, UpdateDrop, LinkDegrade,
                AggregatorFailure, NetworkPartition)
}


def fault_from_dict(d: dict) -> FaultEvent:
    """Inverse of ``FaultEvent.to_dict`` (tag key ``"fault"``)."""
    d = dict(d)
    name = d.pop("fault", None)
    cls = _FAULT_TYPES.get(name)
    if cls is None:
        raise ValueError(
            f"unknown fault type {name!r}; known: "
            f"{sorted(_FAULT_TYPES)}")
    known = {f.name for f in fields(cls)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(f"unknown fields {unknown} for fault {name}")
    if "clients" in d:
        d["clients"] = tuple(int(c) for c in d["clients"])
    return cls(**d)


@dataclass(frozen=True)
class FaultProfile:
    """Per-round fault rates for :meth:`FaultSchedule.generate`.

    Rates are per-round Bernoulli probabilities of injecting ONE event
    of that kind (on a uniformly drawn client); ``agg_fail_every`` is a
    cadence (every k-th round the current host of a uniformly drawn
    slot crashes). ``first_round`` leaves the run's opening rounds
    fault-free so every strategy sees at least one clean placement.
    """
    crash_rate: float = 0.0
    crash_down_rounds: int = 2
    drop_rate: float = 0.0
    degrade_rate: float = 0.0
    degrade_factor: float = 4.0
    degrade_rounds: int = 2
    partition_rate: float = 0.0
    partition_frac: float = 0.2
    partition_rounds: int = 1
    agg_fail_every: int = 0
    agg_down_rounds: int = 1
    first_round: int = 1
    max_offset: float = 0.5

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultProfile":
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(
                f"unknown FaultProfile fields {unknown}; known: "
                f"{sorted(known)}")
        return cls(**d)


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable, replayable list of fault events."""
    events: Tuple[FaultEvent, ...] = field(default_factory=tuple)

    @property
    def empty(self) -> bool:
        return not self.events

    def for_round(self, round_idx: int) -> Tuple[FaultEvent, ...]:
        """This round's faults in canonical order: (offset, type name,
        schedule position) — deterministic regardless of construction
        order."""
        hits = [(ev.offset, type(ev).__name__, i, ev)
                for i, ev in enumerate(self.events)
                if ev.at_round == round_idx]
        return tuple(ev for _off, _name, _i, ev in sorted(
            hits, key=lambda h: h[:3]))

    def to_dicts(self) -> list:
        return [ev.to_dict() for ev in self.events]

    @classmethod
    def from_dicts(cls, dicts) -> "FaultSchedule":
        return cls(tuple(fault_from_dict(d) for d in dicts))

    @classmethod
    def generate(cls, profile: FaultProfile, *, seed: int,
                 n_clients: int, n_slots: int,
                 rounds: int) -> "FaultSchedule":
        """Draw a randomized-but-seeded schedule from ``profile``.

        All draws come from the dedicated ``(seed, _FAULT_STREAM)``
        stream in a fixed per-round order (crash, drop, degrade,
        partition, aggregator failure), so the schedule is a pure
        function of ``(profile, seed, n_clients, n_slots, rounds)``.
        """
        rng = np.random.default_rng((int(seed), _FAULT_STREAM))
        out = []
        for r in range(int(profile.first_round), int(rounds)):
            if profile.crash_rate > 0 and rng.random() < profile.crash_rate:
                out.append(ClientCrash(
                    at_round=r,
                    offset=float(rng.uniform(0.0, profile.max_offset)),
                    client=int(rng.integers(n_clients)),
                    down_rounds=int(profile.crash_down_rounds)))
            if profile.drop_rate > 0 and rng.random() < profile.drop_rate:
                out.append(UpdateDrop(
                    at_round=r,
                    offset=float(rng.uniform(0.0, profile.max_offset)),
                    client=int(rng.integers(n_clients))))
            if (profile.degrade_rate > 0
                    and rng.random() < profile.degrade_rate):
                out.append(LinkDegrade(
                    at_round=r, offset=0.0,
                    client=int(rng.integers(n_clients)),
                    factor=float(profile.degrade_factor),
                    for_rounds=int(profile.degrade_rounds)))
            if (profile.partition_rate > 0
                    and rng.random() < profile.partition_rate):
                k = max(1, int(round(profile.partition_frac * n_clients)))
                picks = rng.choice(n_clients, size=k, replace=False)
                out.append(NetworkPartition(
                    at_round=r, offset=0.0,
                    clients=tuple(int(c) for c in np.sort(picks)),
                    for_rounds=int(profile.partition_rounds)))
            if (profile.agg_fail_every > 0
                    and (r - profile.first_round) % profile.agg_fail_every
                    == profile.agg_fail_every - 1):
                out.append(AggregatorFailure(
                    at_round=r,
                    offset=float(rng.uniform(0.0, profile.max_offset)),
                    slot=int(rng.integers(n_slots)),
                    down_rounds=int(profile.agg_down_rounds)))
        return cls(tuple(out))


@dataclass(frozen=True)
class FaultAt:
    """VirtualClock wrapper: ``fault`` fires when this event pops."""
    fault: FaultEvent


__all__ = [
    "AggregatorFailure",
    "ClientCrash",
    "ClientRecover",
    "FaultAt",
    "FaultEvent",
    "FaultProfile",
    "FaultSchedule",
    "LinkDegrade",
    "NetworkPartition",
    "UpdateDrop",
    "fault_from_dict",
]
