"""The SDFL coordinator: federated rounds with black-box TPD measurement.

This is the single-host emulation of the paper's docker/MQTT deployment
(Sec. IV-C): N heterogeneous clients train a real model (the paper's
1.8M-param MLP by default) on non-IID partitions; every round a
placement strategy proposes the aggregation tree; aggregation is
actually computed cluster-by-cluster with per-cluster timing; the
round's Total Processing Delay composes the per-cluster times exactly
like the physical system would experience them:

    TPD = max_c (local train time) + sum_levels max_cluster (agg time)

Heterogeneity: each client's measured compute time is scaled by
1/pspeed_c — the emulation analogue of the paper's docker cpu/memory
limits. The coordinator never reads pspeed to *decide* anything: the
strategy only ever sees the final TPD (black-box, as in the paper).

Two round engines drive the same semantics:

* ``engine='batched'`` (default): client params ride a leading ``C``
  dim; local training is ONE jit'd ``vmap``-of-``scan`` per round (per
  batch-shape bucket) and aggregation is ONE jit'd weighted
  ``segment_sum`` per tree level, driven by ``Hierarchy.round_plan``
  index tables. This is what scales the emulation past a few dozen
  clients (benchmarks/bench_round_engine.py sweeps 16 -> 256).
* ``engine='loop'``: the original per-client / per-cluster dispatch.
  Its wall-clock timing is per-cluster-faithful (the docker-faithful
  'measured' mode on a quiet box); the batched engine necessarily
  *attributes* measured wall time across clients/clusters by load share
  instead. Deterministic timing is identical between engines.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hierarchy import ClientPool, Hierarchy, TopologyUpdate, slot_remap
from repro.core.placement import PlacementStrategy
from repro.data.synthetic import FederatedDataset
from repro.faults.tolerance import quorum_count, quorum_merge_batched
from repro.fl.aggregation import SegmentAggregator
from repro.fl.distributed import elastic_rehierarchize
from repro.models.api import Model
from repro.utils.trees import tree_weighted_sum

# rng stream tag for elastic data provisioning: joiner shards draw from
# a dedicated stream so admitting clients never perturbs the training /
# noise rng sequences of the surviving population
_ELASTIC_STREAM = 0xE1A57


@dataclass
class RoundRecord:
    round_idx: int
    placement: list
    tpd: float
    train_time: float
    agg_time: float
    loss: float
    accuracy: float


@dataclass
class FederatedRunResult:
    strategy: str
    rounds: List[RoundRecord] = field(default_factory=list)

    @property
    def tpds(self) -> np.ndarray:
        return np.asarray([r.tpd for r in self.rounds])

    @property
    def total_processing_time(self) -> float:
        return float(self.tpds.sum())

    def summary(self) -> dict:
        if not self.rounds:  # zero rounds: well-defined empties, no NaN
            return {"strategy": self.strategy, "rounds": 0,
                    "total_tpd": 0.0, "mean_tpd": 0.0,
                    "last10_mean_tpd": 0.0, "final_accuracy": 0.0}
        return {
            "strategy": self.strategy,
            "rounds": len(self.rounds),
            "total_tpd": self.total_processing_time,
            "mean_tpd": float(self.tpds.mean()),
            "last10_mean_tpd": float(self.tpds[-10:].mean()),
            "final_accuracy": self.rounds[-1].accuracy,
        }


class FederatedOrchestrator:
    """Runs FL rounds against a strategy, measuring black-box TPD.

    The training population is ELASTIC: :meth:`admit` / :meth:`retire`
    resize the live run mid-flight (joiners train from the current
    global model and get fresh data shards; survivors keep theirs), and
    :meth:`sync_population` reconciles hierarchy/data/engine state after
    event-driven pool resizes — see the elastic section below."""

    def __init__(self, model: Model, hierarchy: Hierarchy,
                 clients: ClientPool, data: FederatedDataset, *,
                 local_lr: float = 0.05, local_steps: int = 4,
                 batch_size: int = 32, time_scale: float = 1.0,
                 comm_latency: float = 0.0, seed: int = 0,
                 rng_noise: float = 0.0, timing: str = "measured",
                 engine: str = "auto"):
        """``timing``: 'measured' uses wall-clock (the docker-faithful
        mode — requires a quiet machine); 'deterministic' charges eq.6
        unit-work/pspeed delays through the SAME black-box interface
        (reproducible on loaded CI boxes). Training math is identical.

        ``engine``: 'batched' (vmap'd clients + segment-sum levels),
        'loop' (per-client dispatch), or 'auto' (batched)."""
        assert len(clients) == hierarchy.total_clients == data.n_clients
        self.model = model
        self.hierarchy = hierarchy
        self.clients = clients
        self.data = data
        self.local_steps = local_steps
        self.batch_size = batch_size
        self.time_scale = time_scale
        self.comm_latency = comm_latency
        self.rng = np.random.default_rng(seed)
        self.rng_noise = rng_noise
        assert timing in ("measured", "deterministic")
        self.timing = timing
        assert engine in ("auto", "loop", "batched")
        self.engine = "batched" if engine == "auto" else engine

        self.params = model.init(jax.random.key(seed))
        self.local_lr = local_lr
        self._grad_step = jax.jit(jax.value_and_grad(
            lambda p, b: model.loss_fn(p, b)[0]), static_argnames=())
        self._eval = jax.jit(lambda p, b: model.loss_fn(p, b),
                             static_argnames=())
        self.weights = data.client_weights()

        # weighted-sum of a cluster's updates, jit'd once (loop engine)
        self._wsum = jax.jit(
            lambda trees, w: tree_weighted_sum(trees, w),
            static_argnames=())

        # batched engine state (built lazily in _warmup)
        self._agg: Optional[SegmentAggregator] = None
        self._local_fns: Dict[tuple, Callable] = {}

        # elastic population state: the hierarchy is a versioned run
        # property (mirrors SimulatedEnvironment); the capacity window
        # honors a deliberately overstuffed construction-time population
        self.topology_version = 0
        self._capacity = max(hierarchy.max_clients, len(clients))
        self._elastic_rng = np.random.default_rng((seed, _ELASTIC_STREAM))

        # trace recording (repro.calibration): when enabled, each round
        # captures per-client train times and per-level/per-cluster
        # aggregation delays into ``last_timings``. Recording reads
        # values the engines already computed — no extra rng draws, no
        # numeric changes — so recording=off runs are byte-identical.
        self.record_timings = False
        self.last_timings: Optional[dict] = None
        self._trace: Optional[dict] = None

    # ==================================================================
    # deterministic per-cluster delay (eq. 6), shared by both engines
    # ==================================================================
    # eq. 6 payload units / this = charged delay units: puts aggregation
    # in the paper's regime — the 30 MB JSON model on a 64 MB container
    # dominated the 20-30 s docker rounds, and placement moves exactly
    # this term
    EQ6_PAYLOAD_SCALE = 10.0

    def _det_cluster_work(self, member_clients: Sequence[int]) -> float:
        """eq. 6 payload units: own + ACTUAL children model payloads."""
        mds = self.clients.mdatasize
        return float(sum(mds[int(c)] for c in member_clients)) \
            / self.EQ6_PAYLOAD_SCALE

    def _cluster_time(self, host: int, dt: float, n_parts: int) -> float:
        """Emulated heterogeneity + comm hops + optional noise."""
        t = dt / self.clients.pspeed[host] + self.comm_latency * n_parts
        if self.rng_noise:
            t *= 1.0 + self.rng.normal(0, self.rng_noise)
        return t

    # ==================================================================
    # loop engine (original per-client / per-cluster dispatch)
    # ==================================================================
    def _local_train(self, client_id: int, round_idx: int):
        """Client's local steps. Returns (new_params, loss, measured_time)."""
        params = self.params
        t0 = time.perf_counter()
        loss = 0.0
        for s in range(self.local_steps):
            batch = self.data.client_batch(client_id, self.batch_size,
                                           round_idx * self.local_steps + s)
            lval, grads = self._grad_step(params, batch)
            params = jax.tree.map(
                lambda p, g: p - self.local_lr * g, params, grads)
            loss = float(lval)
        jax.block_until_ready(jax.tree.leaves(params)[0])
        if self.timing == "deterministic":
            dt = float(self.local_steps)  # unit work per local step
        else:
            dt = time.perf_counter() - t0
        return params, loss, dt / self.clients.pspeed[client_id]

    def _aggregate(self, updates: List, placement: np.ndarray):
        """Cluster-by-cluster aggregation with per-cluster timing.

        Returns (global_params, total_agg_time) where total_agg_time =
        sum over levels of the level's max cluster time (eq. 7 semantics,
        with per-cluster times instead of the model's estimate).
        """
        h = self.hierarchy
        weighted = [jax.tree.map(lambda x, w=w: x * w, u)
                    for u, w in zip(updates, self.weights, strict=True)]
        trainers = h.trainer_assignment(placement)
        slot_value = [None] * h.dimensions
        mds = self.clients.mdatasize
        total = 0.0
        for level in range(h.depth - 1, -1, -1):
            level_max = 0.0
            row = None
            if self._trace is not None:
                row = {"level": level, "slots": [], "hosts": [],
                       "loads": [], "n_parts": [], "delays": []}
            for s in range(h.level_starts[level], h.level_starts[level + 1]):
                host = int(placement[s])
                parts = [weighted[host]]
                members = [host]
                kids = h.children_slots(s)
                if kids:
                    parts.extend(slot_value[k] for k in kids)
                    members.extend(int(placement[k]) for k in kids)
                else:
                    li = s - h.level_starts[h.depth - 1]
                    parts.extend(weighted[t] for t in trainers[li])
                    members.extend(trainers[li])
                t0 = time.perf_counter()
                acc = self._wsum(parts, [1.0] * len(parts))
                jax.block_until_ready(jax.tree.leaves(acc)[0])
                if self.timing == "deterministic":
                    dt = self._det_cluster_work(members)
                else:
                    dt = time.perf_counter() - t0
                slot_value[s] = acc
                cluster_t = self._cluster_time(host, dt, len(parts))
                if row is not None:
                    row["slots"].append(s)
                    row["hosts"].append(host)
                    row["loads"].append(
                        float(sum(mds[int(c)] for c in members)))
                    row["n_parts"].append(len(parts))
                    row["delays"].append(float(cluster_t))
                level_max = max(level_max, cluster_t)
            if row is not None:
                self._trace["levels"].append(row)
            total += level_max
        return slot_value[0], total

    def _round_loop(self, r: int, placement: np.ndarray):
        updates, train_times = [], []
        for c in range(self.hierarchy.total_clients):
            p, _, t = self._local_train(c, r)
            updates.append(p)
            train_times.append(t)
        if self._trace is not None:
            self._trace["train"] = {
                "clients": list(range(self.hierarchy.total_clients)),
                "times": [float(t) for t in train_times]}
        new_params, agg_time = self._aggregate(updates, placement)
        return new_params, max(train_times), agg_time

    # ==================================================================
    # batched engine: vmap'd local steps + per-level segment sums
    # ==================================================================
    def _collect_batches(self, round_idx: int, ids=None):
        """Per-client step batches, bucketed by batch shape.

        Returns [(client_ids, stacked)] where stacked leaves are
        (C_bucket, local_steps, batch, ...) — identical values to what
        the loop engine would feed step-by-step. ``ids`` restricts the
        cohort (the online track trains partial cohorts); ``None``
        means every client, in id order.
        """
        if ids is None:
            ids = range(self.hierarchy.total_clients)
        buckets: Dict[tuple, list] = {}
        for c in ids:
            c = int(c)
            steps = [self.data.client_batch(
                c, self.batch_size, round_idx * self.local_steps + s)
                for s in range(self.local_steps)]
            sig = tuple(sorted((k, v.shape, str(np.asarray(v).dtype))
                               for k, v in steps[0].items()))
            buckets.setdefault(sig, []).append((c, steps))
        out = []
        for _sig, entries in buckets.items():
            ids = np.asarray([c for c, _ in entries], np.int64)
            keys = entries[0][1][0].keys()
            stacked = {k: np.stack([np.stack([np.asarray(st[k])
                                              for st in steps])
                                    for _, steps in entries])
                       for k in keys}
            out.append((ids, stacked))
        return out

    def _local_fn_for(self, sig: tuple) -> Callable:
        fn = self._local_fns.get(sig)
        if fn is not None:
            return fn
        loss_fn = self.model.loss_fn
        lr = self.local_lr

        def local_all(params, batches):
            def per_client(client_batches):
                def step(p, b):
                    lval, g = jax.value_and_grad(
                        lambda q: loss_fn(q, b)[0])(p)
                    return jax.tree.map(
                        lambda x, gg: x - lr * gg, p, g), lval

                final, losses = jax.lax.scan(step, params, client_batches)
                return final, losses[-1]

            return jax.vmap(per_client)(batches)

        fn = jax.jit(local_all, static_argnames=())
        self._local_fns[sig] = fn
        return fn

    def _train_all_batched(self, round_idx: int):
        """All clients' local training. Returns (stacked_updates (C,...),
        train_times (C,))."""
        C = self.hierarchy.total_clients
        t0 = time.perf_counter()
        pieces: List[Tuple[np.ndarray, object]] = []
        for ids, stacked in self._collect_batches(round_idx):
            sig = tuple(sorted((k, v.shape[2:], str(v.dtype))
                               for k, v in stacked.items()))
            new_p, _ = self._local_fn_for(sig)(self.params, stacked)
            pieces.append((ids, new_p))
        jax.block_until_ready(jax.tree.leaves(pieces[-1][1])[0])
        wall = time.perf_counter() - t0

        if len(pieces) == 1 and np.array_equal(
                pieces[0][0], np.arange(C)):
            stacked_updates = pieces[0][1]
        else:
            order = np.concatenate([ids for ids, _ in pieces])
            perm = jnp.asarray(np.argsort(order))
            stacked_updates = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0)[perm],
                *[p for _, p in pieces])

        if self.timing == "deterministic":
            per_client_dt = float(self.local_steps)
        else:
            # one fused dispatch: attribute wall time evenly (the loop
            # engine measures each client; here C clients share the call)
            per_client_dt = wall / C
        train_times = per_client_dt / self.clients.pspeed
        return stacked_updates, train_times

    def _agg_batched(self, stacked_updates, placement: np.ndarray):
        """Per-level segment-sum aggregation + per-cluster timing charge.

        Deterministic timing charges eq. 6 from the plan's ACTUAL member
        payloads (same formula, same rng stream as the loop engine);
        measured timing splits each level's wall clock across its
        clusters by payload share before the pspeed/comm composition.
        """
        h = self.hierarchy
        plan = h.round_plan(placement)
        mds = self.clients.mdatasize
        depth = h.depth

        def level_time(lp, cluster_dt, idx, raw_loads) -> float:
            """pspeed/comm/noise composition, vectorized per level (one
            rng draw per cluster, same stream order as the loop engine)."""
            ts = (cluster_dt / self.clients.pspeed[lp.hosts]
                  + self.comm_latency * lp.n_parts)
            if self.rng_noise:
                ts = ts * (1.0 + self.rng.normal(0, self.rng_noise,
                                                 size=lp.n_clusters))
            if self._trace is not None:
                level = depth - 1 - idx  # plan levels are deepest first
                start = h.level_starts[level]
                self._trace["levels"].append({
                    "level": level,
                    "slots": list(range(start, start + lp.n_clusters)),
                    "hosts": lp.hosts.tolist(),
                    "loads": np.asarray(raw_loads, np.float64).tolist(),
                    "n_parts": lp.n_parts.tolist(),
                    "delays": np.asarray(ts, np.float64).tolist()})
            return float(ts.max())

        if self.timing == "deterministic":
            # charge eq. 6 analytically; run the whole aggregation as
            # ONE jit call (no per-level host syncs needed)
            new_global = self._agg.aggregate_fused(
                stacked_updates, self.weights, plan)
            total = 0.0
            for idx, lp in enumerate(plan.levels):
                loads = np.zeros(lp.n_clusters)
                np.add.at(loads, lp.seg, mds[lp.member_clients])
                total += level_time(lp, loads / self.EQ6_PAYLOAD_SCALE,
                                    idx, loads)
            return new_global, total

        weighted = self._agg.weighted(stacked_updates, self.weights)
        total = 0.0
        vals = None
        for idx, lp in enumerate(plan.levels):
            t0 = time.perf_counter()
            vals = self._agg.run_level(idx, weighted, vals, plan)
            jax.block_until_ready(jax.tree.leaves(vals)[0])
            wall = time.perf_counter() - t0
            loads = np.zeros(lp.n_clusters)
            np.add.at(loads, lp.seg, mds[lp.member_clients])
            total += level_time(lp, wall * loads / max(loads.sum(), 1e-12),
                                idx, loads)
        return jax.tree.map(lambda x: x[0], vals), total

    def _round_batched(self, r: int, placement: np.ndarray):
        if self._agg is None:
            self._agg = SegmentAggregator(self.hierarchy)
        stacked_updates, train_times = self._train_all_batched(r)
        if self._trace is not None:
            self._trace["train"] = {
                "clients": list(range(self.hierarchy.total_clients)),
                "times": np.asarray(train_times, np.float64).tolist()}
        new_params, agg_time = self._agg_batched(stacked_updates, placement)
        return new_params, float(np.max(train_times)), agg_time

    # ==================================================================
    # partial-cohort hooks (the online track's building blocks)
    # ==================================================================
    def train_cohort(self, ids, round_idx: int):
        """Local training for a client subset, from the CURRENT global.

        ``ids`` must be strictly increasing. Returns ``(stacked_updates,
        train_times)`` row-aligned to ``ids``. A full-population cohort
        routes through ``_train_all_batched`` — the exact executable
        ``run_round`` uses — so a full-cohort call is bit-identical to
        the synchronous round's training half (the degenerate parity
        pin rides on this). Partial cohorts share the same per-bucket
        jit'd fns; only the leading client axis differs.
        """
        ids = np.asarray(ids, np.int64)
        self._check_population()
        C = self.hierarchy.total_clients
        if ids.size and np.any(np.diff(ids) <= 0):
            raise ValueError("train_cohort ids must be strictly increasing")
        if ids.size == C:
            return self._train_all_batched(round_idx)
        if ids.size == 0:
            return None, np.zeros(0, np.float64)
        t0 = time.perf_counter()
        pieces: List[Tuple[np.ndarray, object]] = []
        for bucket_ids, stacked in self._collect_batches(round_idx, ids):
            sig = tuple(sorted((k, v.shape[2:], str(v.dtype))
                               for k, v in stacked.items()))
            new_p, _ = self._local_fn_for(sig)(self.params, stacked)
            pieces.append((bucket_ids, new_p))
        jax.block_until_ready(jax.tree.leaves(pieces[-1][1])[0])
        wall = time.perf_counter() - t0

        order = np.concatenate([b for b, _ in pieces])
        if len(pieces) == 1 and np.array_equal(order, ids):
            stacked_updates = pieces[0][1]
        else:
            # rows land in bucket order; argsort restores ascending id
            # order == the ids order (ids are strictly increasing)
            perm = jnp.asarray(np.argsort(order))
            stacked_updates = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0)[perm],
                *[p for _, p in pieces])

        if self.timing == "deterministic":
            per_client_dt = float(self.local_steps)
        else:
            per_client_dt = wall / ids.size
        train_times = per_client_dt / self.clients.pspeed[ids]
        return stacked_updates, train_times

    def aggregate_cohort(self, stacked_updates, placement):
        """Full-population hierarchical aggregation: the batched
        engine's fused segment-sum path, returning ``(new_global,
        agg_time)`` WITHOUT committing the params (callers decide —
        the online track's degenerate rounds commit via
        :meth:`set_global`). Bit-identical to ``run_round``'s
        aggregation half."""
        placement = np.asarray(placement, np.int64)
        self.hierarchy.validate_placement(placement)
        if self._agg is None:
            self._agg = SegmentAggregator(self.hierarchy)
        return self._agg_batched(stacked_updates, placement)

    def cluster_delay(self, host: int, member_clients, n_parts: int
                      ) -> float:
        """The eq. 6 delay one aggregation flush charges: payload work
        over the ACTUAL members' model sizes, scaled by the host's
        pspeed plus per-part comm latency — the same composition the
        synchronous engines charge per cluster, exposed for the online
        track's per-flush timing."""
        dt = self._det_cluster_work(member_clients)
        return self._cluster_time(int(host), dt, int(n_parts))

    def evaluate_global(self) -> tuple:
        """(loss, accuracy) of the current global params — the same
        eval batch/executable ``run_round`` scores with."""
        return self._evaluate()

    def set_global(self, params) -> None:
        """Commit a new global model (the online root merge's result)."""
        self.params = params

    # ==================================================================
    def _evaluate(self, n: int = 512) -> tuple:
        if hasattr(self.data, "eval_batch"):
            batch = self.data.eval_batch(n)
        else:
            base = self.data.base
            idx = np.arange(min(n, len(base)))
            batch = {"x": base.features[idx], "y": base.labels[idx]}
        loss, metrics = self._eval(self.params, batch)
        return float(loss), float(metrics.get("acc", 0.0))

    # ------------------------------------------------------------------
    def warmup(self) -> None:
        """Trace/compile everything once so round-0 timing is not skewed
        by compilation (the docker system has no such artifact)."""
        if self.engine == "batched":
            if self._agg is None:
                self._agg = SegmentAggregator(self.hierarchy)
            stacked, _ = self._train_all_batched(0)
            noise, self.rng_noise = self.rng_noise, 0.0  # keep rng stream
            try:
                self._agg_batched(stacked,
                                  np.arange(self.hierarchy.dimensions))
            finally:
                self.rng_noise = noise
            self._evaluate()
            return
        batch = self.data.client_batch(0, self.batch_size, 0)
        lval, g = self._grad_step(self.params, batch)
        jax.block_until_ready(lval)
        h = self.hierarchy
        n_pool = h.total_clients - h.dimensions
        base, extra = divmod(n_pool, h.n_leaves)
        sizes = {h.width + 1, base + 1} | ({base + 2} if extra else set())
        for k in sorted(sizes):
            acc = self._wsum([self.params] * k, [1.0] * k)
            jax.block_until_ready(jax.tree.leaves(acc)[0])
        self._evaluate()

    # kept as an alias for callers of the historical private name
    _warmup = warmup

    # ==================================================================
    # elastic population: admit / retire / sync_population
    # ==================================================================
    def admit(self, memcap, pspeed, mdatasize=None
              ) -> Tuple[np.ndarray, Optional[TopologyUpdate]]:
        """Admit fresh clients into the LIVE training population.

        Appends the devices to the pool, provisions each a data shard
        (``FederatedDataset.resize`` — survivors keep their exact
        shards), recomputes the FedAvg weights, and re-hierarchizes when
        the growth crosses the tree's capacity window. Returns ``(new
        client ids, TopologyUpdate or None)`` — callers driving a
        placement strategy must ``strategy.migrate(update)`` before the
        next ``run_round``, exactly as the experiment runner does.

        Joiners hold no model/optimizer state of their own: every round
        starts each client's local steps from the CURRENT global
        ``self.params``, so a mid-run joiner's first gradient step is
        taken from the model the federation has already trained — never
        from the round-0 init (pinned by the elastic-emulated tests).
        """
        ids = self.clients.join(memcap, pspeed, mdatasize)
        return ids, self.sync_population()

    def retire(self, ids) -> Optional[TopologyUpdate]:
        """Retire clients from the live population: their data shards
        are dropped, survivors are renumbered contiguously, and the
        returned :class:`TopologyUpdate` carries the old->new id remap
        plus the ``slot_remap`` every strategy's ``migrate`` hook uses
        to REPAIR placements — a departure taking out a current
        aggregator host yields a valid repaired placement for the very
        next round."""
        self.clients.leave(ids)
        return self.sync_population()

    def sync_population(self) -> Optional[TopologyUpdate]:
        """Reconcile hierarchy + data + engine state with the (possibly
        resized) client pool; ``None`` when the population is untouched.

        This is the emulated twin of
        ``SimulatedEnvironment.sync_topology``: it drains the pool's
        resize log, carries surviving data shards across the id remap
        (provisioning joiners via ``repro.data.synthetic``), recomputes
        the FedAvg weights, re-hierarchizes through the SAME
        capacity-window rule (:func:`elastic_rehierarchize`, so both
        tracks replay identical hierarchy sequences for one event
        schedule), and retargets the batched round engine — the
        segment-sum executables are re-jitted only when the tree shape
        actually changed. Events mutating the pool directly
        (``ClientJoin``/``ClientLeave``) are wired here through
        ``EmulatedEnvironment.sync_topology``.
        """
        drained = self.clients.drain_resizes()
        if drained is None:
            return None
        old_n, client_remap = drained
        old_h = self.hierarchy
        if old_n != old_h.total_clients:
            raise RuntimeError(
                f"pool resize log starts at {old_n} clients but the "
                f"hierarchy tracked {old_h.total_clients}")
        n = len(self.clients)
        resize = getattr(self.data, "resize", None)
        if resize is None:
            raise NotImplementedError(
                f"{type(self.data).__name__} has no resize(); elastic "
                f"populations need a dataset that can carry shards "
                f"across a pool resize")
        resize(client_remap, n, self._elastic_rng)
        self.weights = self.data.client_weights()
        new_h, self._capacity = elastic_rehierarchize(old_h, n,
                                                      self._capacity)
        self.topology_version += 1
        update = TopologyUpdate(
            version=self.topology_version,
            old_hierarchy=old_h, new_hierarchy=new_h,
            slot_remap=slot_remap(old_h, new_h),
            client_remap=client_remap)
        self.hierarchy = new_h
        if self._agg is not None:
            self._agg.retarget(new_h)
        return update

    def _check_population(self) -> None:
        """Round-time invariant: the population must be synced."""
        if self.clients.pending_remap() is not None:
            raise RuntimeError(
                "client pool was resized without sync_population(); use "
                "admit()/retire() (or drive rounds through "
                "EmulatedEnvironment, whose sync_topology wires "
                "ClientJoin/ClientLeave events here)")
        if not (len(self.clients) == self.hierarchy.total_clients
                == self.data.n_clients):
            raise RuntimeError(
                f"inconsistent population: pool={len(self.clients)} "
                f"hierarchy={self.hierarchy.total_clients} "
                f"data={self.data.n_clients}")

    def run_round(self, r: int, placement) -> RoundRecord:
        """Execute ONE federated round at ``placement`` and return its
        record (the black-box TPD plus train/agg split and eval metrics).

        This is the single step both ``run`` and the experiment API's
        ``EmulatedEnvironment`` drive, so a strategy observed through
        either path sees bit-identical TPDs. Call ``warmup()`` once
        before the first round.
        """
        placement = np.asarray(placement, np.int64)
        self._check_population()
        self.hierarchy.validate_placement(placement)

        self.last_timings = None
        if self.record_timings:
            self._trace = {"train": {"clients": [], "times": []},
                           "levels": []}
        try:
            if self.engine == "loop":
                new_params, train_time, agg_time = \
                    self._round_loop(r, placement)
            else:
                new_params, train_time, agg_time = \
                    self._round_batched(r, placement)
        finally:
            if self._trace is not None:
                self._trace["train_time"] = 0.0
                self._trace["agg_time"] = 0.0
                self.last_timings, self._trace = self._trace, None
        self.params = new_params
        if self.last_timings is not None:
            self.last_timings["train_time"] = float(train_time)
            self.last_timings["agg_time"] = float(agg_time)

        tpd = (train_time + agg_time) * self.time_scale
        loss, acc = self._evaluate()
        return RoundRecord(
            round_idx=r, placement=placement.tolist(), tpd=tpd,
            train_time=train_time, agg_time=agg_time,
            loss=loss, accuracy=acc)

    def run_round_faulty(self, r: int, placement, *, down=(), dropped=(),
                         degraded=None, quorum_frac: float = 0.0
                         ) -> Tuple[RoundRecord, Dict[str, float]]:
        """One federated round under faults (the emulated track's fault
        path; ``repro.faults``).

        ``down`` clients (crashed or partitioned this round) neither
        train nor deliver; ``dropped`` clients train but their updates
        are lost in transit; ``degraded`` maps clients to train-delay
        multipliers. Down aggregator HOSTS fail over to the lowest-id
        live unplaced client (black-box: no pspeed peeking). Surviving
        updates merge FLAT at the root — hierarchical FedAvg over the
        tree equals flat weighted FedAvg (the segment-sum invariant) —
        through :func:`quorum_merge_batched`, gated on
        live-population quorum and damped by the arrived fraction; a
        refused merge leaves the model untouched (a degraded flush).
        Aggregation time charges the eq. 6 per-cluster walk over the
        payloads actually present.

        A round with NO faults delegates to :meth:`run_round` verbatim,
        so a zero-fault schedule stays bit-identical to the fault-free
        track (the parity pin). Returns ``(record, extra)`` where
        ``extra`` carries the fault series (merged / degraded_flushes /
        failovers / dropped_updates / down).
        """
        placement = np.asarray(placement, np.int64)
        self._check_population()
        self.hierarchy.validate_placement(placement)
        down = {int(c) for c in down}
        dropped = {int(c) for c in dropped}
        degraded = {int(c): float(f)
                    for c, f in sorted((degraded or {}).items())}
        C = self.hierarchy.total_clients
        if not down and not dropped and not degraded:
            rec = self.run_round(r, placement)
            return rec, {"merged": float(C), "degraded_flushes": 0.0,
                         "failovers": 0.0, "dropped_updates": 0.0,
                         "down": 0.0}
        if self.timing != "deterministic":
            raise ValueError(
                "run_round_faulty composes per-cluster delays "
                "analytically and needs timing='deterministic', got "
                f"{self.timing!r}")
        if self.engine != "batched":
            raise ValueError("run_round_faulty needs the batched round "
                             f"engine, got {self.engine!r}")

        cohort = np.asarray([c for c in range(C) if c not in down],
                            np.int64)
        if cohort.size == 0:
            raise RuntimeError(f"round {r}: every client is down")

        # aggregator failover: repair down hosts before anything runs
        eff = placement.copy()
        placed = {int(c) for c in eff}
        failovers = 0
        for s in range(len(eff)):
            if int(eff[s]) in down:
                repl = -1
                for c in range(C):
                    if c not in down and c not in placed:
                        repl = c
                        break
                if repl < 0:
                    raise RuntimeError(
                        f"aggregator failover for slot {s}: no live "
                        "unplaced client left")
                eff[s] = repl
                placed.add(repl)
                failovers += 1
        self.hierarchy.validate_placement(eff)

        stacked, train_times = self.train_cohort(cohort, r)
        train_times = np.asarray(train_times, np.float64).copy()
        for j in range(cohort.size):
            factor = degraded.get(int(cohort[j]))
            if factor is not None:
                train_times[j] *= factor
        train_time = float(train_times.max())

        merged_ids = np.asarray(
            [c for c in cohort.tolist() if c not in dropped], np.int64)
        need = quorum_count(max(1, C - len(down)), quorum_frac)
        if merged_ids.size < need:
            agg_time = 0.0
            merged = 0
            degraded_flush = 1.0
        else:
            rows = np.searchsorted(cohort, merged_ids)
            sub = jax.tree.map(lambda x: x[jnp.asarray(rows)], stacked)
            base_w = self.weights[merged_ids]
            stal = np.zeros(merged_ids.size, np.float64)
            self.params = quorum_merge_batched(
                self.params, sub, base_w, stal, 0.0, 1.0,
                merged_ids.size / C)
            agg_time = self._faulty_agg_time(
                eff, {int(c) for c in merged_ids})
            merged = int(merged_ids.size)
            degraded_flush = 0.0

        tpd = (train_time + agg_time) * self.time_scale
        loss, acc = self._evaluate()
        rec = RoundRecord(
            round_idx=r, placement=eff.tolist(), tpd=tpd,
            train_time=train_time, agg_time=agg_time,
            loss=loss, accuracy=acc)
        extra = {
            "merged": float(merged),
            "degraded_flushes": degraded_flush,
            "failovers": float(failovers),
            "dropped_updates": float(
                len(dropped & {int(c) for c in cohort})),
            "down": float(len(down))}
        return rec, extra

    def _faulty_agg_time(self, placement: np.ndarray, merged: set
                         ) -> float:
        """eq. 7 composition of eq. 6 per-cluster delays over the
        payloads PRESENT under faults: a leaf cluster charges its
        merged trainers (plus the host's own update if it merged), an
        inner cluster charges its child hosts' forwarded partials.
        Reduces to the full ``_aggregate`` walk when everything merged."""
        h = self.hierarchy
        trainers = h.trainer_assignment(placement)
        leaf_start = h.level_starts[h.depth - 1]
        total = 0.0
        for level in range(h.depth - 1, -1, -1):
            level_max = 0.0
            for s in range(h.level_starts[level],
                           h.level_starts[level + 1]):
                host = int(placement[s])
                kids = h.children_slots(s)
                if kids:
                    present = [int(placement[k]) for k in kids]
                else:
                    li = s - leaf_start
                    present = [t for t in trainers[li] if t in merged]
                if host in merged:
                    present = [host] + present
                if not present:
                    continue
                dt = self._det_cluster_work(present)
                level_max = max(
                    level_max,
                    self._cluster_time(host, dt, len(present)))
            total += level_max
        return total

    # ==================================================================
    # checkpoint support: the non-pytree runtime state
    # ==================================================================
    def runtime_state(self) -> dict:
        """JSON-safe snapshot of the orchestrator state that is NOT the
        params pytree (which checkpoints through the npz payload): the
        rng stream positions and the elastic bookkeeping. Restoring
        both makes a resumed run replay bit-identically."""
        return {"rng": self.rng.bit_generator.state,
                "elastic_rng": self._elastic_rng.bit_generator.state,
                "topology_version": int(self.topology_version),
                "capacity": int(self._capacity)}

    def load_runtime_state(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self._elastic_rng.bit_generator.state = state["elastic_rng"]
        self.topology_version = int(state["topology_version"])
        self._capacity = int(state["capacity"])

    def run(self, strategy: PlacementStrategy, rounds: int,
            verbose: bool = False) -> FederatedRunResult:
        result = FederatedRunResult(strategy=strategy.name)
        self.warmup()
        for r in range(rounds):
            placement = np.asarray(strategy.propose(r), np.int64)
            record = self.run_round(r, placement)
            strategy.observe(placement, record.tpd)
            result.rounds.append(record)
            if verbose:
                print(f"[{strategy.name}] round {r:3d} "
                      f"tpd={record.tpd:8.4f} "
                      f"loss={record.loss:.4f} acc={record.accuracy:.3f}")
        return result
