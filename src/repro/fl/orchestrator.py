"""The SDFL coordinator: federated rounds with black-box TPD measurement.

This is the single-host emulation of the paper's docker/MQTT deployment
(Sec. IV-C): N heterogeneous clients train a real model (the paper's
1.8M-param MLP by default) on non-IID partitions; every round a
placement strategy proposes the aggregation tree; aggregation is
actually computed cluster-by-cluster with wall-clock timing; the round's
Total Processing Delay composes the measured per-cluster times exactly
like the physical system would experience them:

    TPD = max_c (local train time) + sum_levels max_cluster (agg time)

Heterogeneity: each client's measured compute time is scaled by
1/pspeed_c — the emulation analogue of the paper's docker cpu/memory
limits. The coordinator never reads pspeed to *decide* anything: the
strategy only ever sees the final TPD (black-box, as in the paper).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hierarchy import ClientPool, Hierarchy
from repro.core.placement import PlacementStrategy
from repro.data.synthetic import FederatedDataset
from repro.fl.aggregation import hierarchical_fedavg
from repro.models.api import Model, make_train_step
from repro.optim import sgd
from repro.utils.trees import tree_weighted_sum


@dataclass
class RoundRecord:
    round_idx: int
    placement: list
    tpd: float
    train_time: float
    agg_time: float
    loss: float
    accuracy: float


@dataclass
class FederatedRunResult:
    strategy: str
    rounds: List[RoundRecord] = field(default_factory=list)

    @property
    def tpds(self) -> np.ndarray:
        return np.asarray([r.tpd for r in self.rounds])

    @property
    def total_processing_time(self) -> float:
        return float(self.tpds.sum())

    def summary(self) -> dict:
        return {
            "strategy": self.strategy,
            "rounds": len(self.rounds),
            "total_tpd": self.total_processing_time,
            "mean_tpd": float(self.tpds.mean()),
            "last10_mean_tpd": float(self.tpds[-10:].mean()),
            "final_accuracy": self.rounds[-1].accuracy if self.rounds else 0.0,
        }


class FederatedOrchestrator:
    """Runs FL rounds against a strategy, measuring black-box TPD."""

    def __init__(self, model: Model, hierarchy: Hierarchy,
                 clients: ClientPool, data: FederatedDataset, *,
                 local_lr: float = 0.05, local_steps: int = 4,
                 batch_size: int = 32, time_scale: float = 1.0,
                 comm_latency: float = 0.0, seed: int = 0,
                 rng_noise: float = 0.0, timing: str = "measured"):
        """``timing``: 'measured' uses wall-clock (the docker-faithful
        mode — requires a quiet machine); 'deterministic' charges eq.6
        unit-work/pspeed delays through the SAME black-box interface
        (reproducible on loaded CI boxes). Training math is identical."""
        assert len(clients) == hierarchy.total_clients == data.n_clients
        self.model = model
        self.hierarchy = hierarchy
        self.clients = clients
        self.data = data
        self.local_steps = local_steps
        self.batch_size = batch_size
        self.time_scale = time_scale
        self.comm_latency = comm_latency
        self.rng = np.random.default_rng(seed)
        self.rng_noise = rng_noise
        assert timing in ("measured", "deterministic")
        self.timing = timing

        self.params = model.init(jax.random.key(seed))
        self.local_lr = local_lr
        self._grad_step = jax.jit(jax.value_and_grad(
            lambda p, b: model.loss_fn(p, b)[0]))
        self._eval = jax.jit(lambda p, b: model.loss_fn(p, b))
        self.weights = data.client_weights()

        # weighted-sum of a cluster's updates, jit'd once
        self._wsum = jax.jit(
            lambda trees, w: tree_weighted_sum(trees, w))

    # ------------------------------------------------------------------
    def _local_train(self, client_id: int, round_idx: int):
        """Client's local steps. Returns (new_params, loss, measured_time)."""
        params = self.params
        t0 = time.perf_counter()
        loss = 0.0
        for s in range(self.local_steps):
            batch = self.data.client_batch(client_id, self.batch_size,
                                           round_idx * self.local_steps + s)
            l, grads = self._grad_step(params, batch)
            params = jax.tree.map(
                lambda p, g: p - self.local_lr * g, params, grads)
            loss = float(l)
        jax.block_until_ready(jax.tree.leaves(params)[0])
        if self.timing == "deterministic":
            dt = float(self.local_steps)  # unit work per local step
        else:
            dt = time.perf_counter() - t0
        return params, loss, dt / self.clients.pspeed[client_id]

    def _aggregate(self, updates: List, placement: np.ndarray):
        """Cluster-by-cluster aggregation with per-cluster timing.

        Returns (global_params, total_agg_time) where total_agg_time =
        sum over levels of the level's max cluster time (eq. 7 semantics,
        with *measured* times instead of the model's estimate).
        """
        h = self.hierarchy
        weighted = [jax.tree.map(lambda x, w=w: x * w, u)
                    for u, w in zip(updates, self.weights)]
        trainers = h.trainer_assignment(placement)
        slot_value = [None] * h.dimensions
        total = 0.0
        for level in range(h.depth - 1, -1, -1):
            level_max = 0.0
            for s in range(h.level_starts[level], h.level_starts[level + 1]):
                host = int(placement[s])
                parts = [weighted[host]]
                kids = h.children_slots(s)
                if kids:
                    parts.extend(slot_value[k] for k in kids)
                else:
                    li = s - h.level_starts[h.depth - 1]
                    parts.extend(weighted[t] for t in trainers[li])
                t0 = time.perf_counter()
                acc = self._wsum(parts, [1.0] * len(parts))
                jax.block_until_ready(jax.tree.leaves(acc)[0])
                if self.timing == "deterministic":
                    # eq. 6: load = own + children model payloads (units).
                    # /10 puts aggregation in the paper's regime — the
                    # 30 MB JSON model on a 64 MB container dominated the
                    # 20-30 s docker rounds, and placement moves exactly
                    # this term.
                    dt = float(self.clients.mdatasize[host]
                               + sum(self.clients.mdatasize[0]
                                     for _ in range(len(parts) - 1))) / 10.0
                else:
                    dt = time.perf_counter() - t0
                slot_value[s] = acc
                # emulated heterogeneity: host speed scales the measured
                # compute; each child contributes a comm hop
                cluster_t = (dt / self.clients.pspeed[host]
                             + self.comm_latency * len(parts))
                if self.rng_noise:
                    cluster_t *= 1.0 + self.rng.normal(0, self.rng_noise)
                level_max = max(level_max, cluster_t)
            total += level_max
        return slot_value[0], total

    def _evaluate(self, n: int = 512) -> tuple:
        if hasattr(self.data, "eval_batch"):
            batch = self.data.eval_batch(n)
        else:
            base = self.data.base
            idx = np.arange(min(n, len(base)))
            batch = {"x": base.features[idx], "y": base.labels[idx]}
        loss, metrics = self._eval(self.params, batch)
        return float(loss), float(metrics.get("acc", 0.0))

    # ------------------------------------------------------------------
    def _warmup(self) -> None:
        """Trace/compile everything once so round-0 timing is not skewed
        by compilation (the docker system has no such artifact)."""
        batch = self.data.client_batch(0, self.batch_size, 0)
        l, g = self._grad_step(self.params, batch)
        jax.block_until_ready(l)
        h = self.hierarchy
        n_pool = h.total_clients - h.dimensions
        base, extra = divmod(n_pool, h.n_leaves)
        sizes = {h.width + 1, base + 1} | ({base + 2} if extra else set())
        for k in sorted(sizes):
            acc = self._wsum([self.params] * k, [1.0] * k)
            jax.block_until_ready(jax.tree.leaves(acc)[0])
        self._evaluate()

    def run(self, strategy: PlacementStrategy, rounds: int,
            verbose: bool = False) -> FederatedRunResult:
        result = FederatedRunResult(strategy=strategy.name)
        self._warmup()
        for r in range(rounds):
            placement = np.asarray(strategy.propose(r), np.int64)
            self.hierarchy.validate_placement(placement)

            updates, losses, train_times = [], [], []
            for c in range(self.hierarchy.total_clients):
                p, l, t = self._local_train(c, r)
                updates.append(p)
                losses.append(l)
                train_times.append(t)

            new_params, agg_time = self._aggregate(updates, placement)
            self.params = new_params

            train_time = max(train_times)
            tpd = (train_time + agg_time) * self.time_scale
            strategy.observe(placement, tpd)

            loss, acc = self._evaluate()
            result.rounds.append(RoundRecord(
                round_idx=r, placement=placement.tolist(), tpd=tpd,
                train_time=train_time, agg_time=agg_time,
                loss=loss, accuracy=acc))
            if verbose:
                print(f"[{strategy.name}] round {r:3d} tpd={tpd:8.4f} "
                      f"loss={loss:.4f} acc={acc:.3f}")
        return result
