"""The paper's technique as a first-class distributed training step.

Mapping SDFL onto a TPU mesh (see DESIGN.md):

* Every FL **client owns a slice of the data axis** and holds its *own*
  replica of the model: parameters carry a leading client dim ``C``
  sharded over ``('pod',) + ('data',)``. Local training is a ``vmap``
  over that dim — embarrassingly parallel, ZERO cross-client collectives
  (GSPMD keeps tensor-parallel ``model``-axis math inside each client).
* One FL round = ``local_steps`` local updates followed by
  **hierarchical aggregation along the placement tree**: a partial-manual
  ``shard_map`` (manual over pod/data, auto over model) running one
  grouped ``psum`` per tree level (``aggregation.hierarchical_psum``).
  The placement decides the groups; the roofline's collective term sees
  exactly the schedule the paper optimizes.
* The flat baseline (CFL) is the same round with a single ungrouped
  psum.

Multi-pod: each pod hosts its own client set (same per-pod placement);
the tree's root level is a ``pmean`` across the ``pod`` axis — the
hierarchy's top level aligned with the DCN boundary.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.hierarchy import Hierarchy
from repro.fl.aggregation import AggregationPlan, flat_psum, hierarchical_psum
from repro.kernels import compat
from repro.models.api import Model


class FLTrainStep:
    """Builder for the federated round step of any zoo ``Model``.

    Produces pure functions over *client-stacked* pytrees: every param
    leaf gets a leading ``n_clients_total`` dim (n_pods * clients_per_pod)
    sharded over the pod+data axes.
    """

    def __init__(self, model: Model, optimizer, hierarchy: Hierarchy,
                 placement: Sequence[int], *,
                 weights: Optional[Sequence[float]] = None,
                 local_steps: int = 1, mode: str = "hierarchical"):
        self.model = model
        self.optimizer = optimizer
        self.hierarchy = hierarchy
        self.placement = np.asarray(placement, np.int64)
        self.local_steps = local_steps
        self.mode = mode
        policy = model.policy
        self.mesh = policy.mesh
        if self.mesh is not None:
            self.n_pods = self.mesh.shape.get("pod", 1)
            self.data_size = self.mesh.shape.get("data", 1)
        else:
            self.n_pods = 1
            self.data_size = hierarchy.total_clients  # host path: 1 dev/client
        self.clients_per_pod = hierarchy.total_clients
        self.n_clients_total = self.clients_per_pod * self.n_pods
        self.plan = AggregationPlan.build(
            hierarchy, self.placement, self.data_size, weights)

    # ------------------------------------------------------------------
    @property
    def client_axes(self):
        if self.mesh is None:
            return None
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        return axes if axes else None

    def stacked_param_pspecs(self):
        """Per-leaf specs: leading client dim over (pod, data); remaining
        dims keep the model-axis sharding from the model's spec_rule
        (fsdp resolves to None — client replicas exclude data-axis FSDP)."""
        base = self.model.param_pspecs()
        c = self.client_axes

        def stackspec(spec):
            parts = [c]
            for s in spec:
                # drop data/pod axes from param dims (used by client dim)
                if s in ("data", "pod") or (isinstance(s, tuple) and
                                            any(a in ("data", "pod") for a in s)):
                    parts.append(None)
                else:
                    parts.append(s)
            return P(*parts)

        return jax.tree.map(stackspec, base,
                            is_leaf=lambda s: isinstance(s, P))

    def init_stacked(self, rng):
        """Stacked params + opt state (all clients start from one init)."""
        params = self.model.init(rng)
        opt_state = self.optimizer.init(params)
        n = self.n_clients_total

        def stack(x):
            return jnp.broadcast_to(x, (n,) + x.shape)

        return (jax.tree.map(stack, params), jax.tree.map(stack, opt_state))

    # ------------------------------------------------------------------
    def make_round_fn(self):
        """(params_stacked, opt_stacked, batch_stacked) ->
        (params_stacked, opt_stacked, metrics).

        batch_stacked leaves: (n_clients_total, per_client_batch, ...).
        """
        model, optimizer = self.model, self.optimizer
        local_steps = self.local_steps
        plan, mode = self.plan, self.mode
        mesh = self.mesh
        pod_axis = "pod" if (mesh is not None and "pod" in mesh.axis_names) \
            else None

        def local_round(params, opt_state, batch):
            def one_step(carry, _):
                params, opt_state = carry
                (loss, _), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, batch)
                params, opt_state = optimizer.update(params, grads, opt_state)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                one_step, (params, opt_state), None, length=local_steps)
            return params, opt_state, losses[-1]

        def aggregate(params_stacked):
            if mesh is None:
                # host path (tests): tree-equivalent weighted FedAvg
                # (plan built with 1 device per client => weight_of_device
                # is exactly the per-client weight)
                from repro.utils.trees import tree_weighted_sum
                updates = [jax.tree.map(lambda x, i=i: x[i], params_stacked)
                           for i in range(self.n_clients_total)]
                glob = tree_weighted_sum(updates,
                                         list(plan.weight_of_device))
                return jax.tree.map(
                    lambda g: jnp.broadcast_to(
                        g, (self.n_clients_total,) + g.shape), glob)

            def agg_body(tree):
                # local view: client dim is size 1 on each device slice
                squeezed = jax.tree.map(lambda x: x[0], tree)
                if mode == "hierarchical":
                    out = hierarchical_psum(squeezed, plan, "data", pod_axis)
                else:
                    out = flat_psum(squeezed, plan, "data", pod_axis)
                return jax.tree.map(lambda x: x[None], out)

            # Full-manual over every mesh axis: the body is elementwise
            # (grouped psums over pod/data), so model-axis shards pass
            # through untouched. Partial-auto shard_map would also work
            # on current JAX, but on 0.4.x it lowers axis_index to a
            # PartitionId op the CPU SPMD partitioner rejects.
            specs = self.stacked_param_pspecs()
            return compat.shard_map(
                agg_body, mesh=mesh,
                in_specs=(specs,), out_specs=specs,
                axis_names=set(mesh.axis_names), check_vma=False,
            )(params_stacked)

        def round_fn(params_stacked, opt_stacked, batch_stacked):
            # spmd_axis_name tells GSPMD the client dim's mesh axes so
            # sharding constraints inside local_round (e.g. the
            # sequence-parallel hints) batch correctly
            spmd = self.client_axes if mesh is not None else None
            vmapped = jax.vmap(local_round, spmd_axis_name=spmd)
            params_stacked, opt_stacked, losses = vmapped(
                params_stacked, opt_stacked, batch_stacked)
            if mode != "none":
                params_stacked = aggregate(params_stacked)
            return params_stacked, opt_stacked, {"loss": jnp.mean(losses)}

        return round_fn

    # ------------------------------------------------------------------
    # repro-lint: disable=RPL001 (shape helper, no vectorized compute to pin)
    def batch_shape(self, shape_cfg) -> dict:
        """Per-client batch split of a global shape."""
        per = shape_cfg.global_batch // self.n_clients_total
        return {"per_client_batch": max(per, 1),
                "n_clients": self.n_clients_total}


# the historical preference ladder (deeper trees first) and, above it,
# the swarm-scale rungs the elastic environments opt into
_BASE_LADDER = ((3, 2, 2), (3, 2, 1), (2, 3, 4), (2, 3, 3),
                (2, 2, 4), (2, 2, 2), (2, 2, 1))
_SCALE_LADDER = ((6, 4, 2), (6, 3, 2), (5, 3, 2), (4, 3, 2),
                 (4, 2, 2)) + _BASE_LADDER


def choose_fl_hierarchy(n_clients: int, *, scale: bool = False) -> Hierarchy:
    """Pick a depth/width whose minimum client count fits ``n_clients``.

    Preference order: deeper trees first (more interesting schedules).
    Extra clients beyond the minimum become additional trainers (the
    round-robin assignment absorbs them).

    ``scale=True`` extends the ladder with the swarm-scale rungs
    (depth-4 .. depth-6, the large-1k/large-10k tree shapes) so a large
    population keeps a proportionate tree instead of collapsing onto
    the 7-slot depth-3 one — this is what the elastic environments use
    to re-hierarchize a GROWING population (a flash crowd climbs
    depth-2 -> -3 -> -4 as it crosses each rung's minimum). The default
    keeps the historical small-cluster ladder, so launch/bench/example
    callers build the same trees as before.
    """
    for depth, width, tpl in (_SCALE_LADDER if scale else _BASE_LADDER):
        if Hierarchy(depth, width, tpl).min_clients <= n_clients:
            return Hierarchy(depth=depth, width=width, trainers_per_leaf=tpl,
                             n_clients=n_clients)
    return Hierarchy(depth=1, width=1, trainers_per_leaf=1,
                     n_clients=max(n_clients, 2))


def elastic_rehierarchize(old: Hierarchy, n_clients: int,
                          capacity: int) -> tuple:
    """THE capacity-window re-hierarchization rule of the elastic tracks.

    Returns ``(new_hierarchy, new_capacity)`` for a population that just
    resized to ``n_clients`` under a tree previously allowed to carry up
    to ``capacity`` clients. Outside the window ``[old.min_clients,
    capacity]`` the structure is rebuilt through
    :func:`choose_fl_hierarchy` (scale ladder) and the capacity re-pins
    to the new tree's bound; inside it, the same tree shape is kept and
    only ``n_clients`` is re-pinned (cheaper migration, identity
    ``slot_remap``). Deterministic — no rng is consumed — and shared by
    ``SimulatedEnvironment.sync_topology`` and
    ``FederatedOrchestrator.sync_population`` so the two tracks replay
    the SAME hierarchy sequence for the same event schedule (the
    emulated-vs-simulated elastic parity tests pin this).
    """
    if n_clients < old.min_clients or n_clients > capacity:
        new = choose_fl_hierarchy(n_clients, scale=True)
        return new, max(new.max_clients, n_clients)
    return Hierarchy(depth=old.depth, width=old.width,
                     trainers_per_leaf=old.trainers_per_leaf,
                     n_clients=n_clients), capacity


def shard_rows(fn, mesh, n_rows: int, axis: str = "rows"):
    """Row-shard a batched evaluator across ``mesh[axis]`` devices.

    ``fn`` maps per-row inputs ``(rows, ...)`` to per-row outputs
    ``(rows,)``. The returned callable splits every input along axis 0
    into per-device shards under ``shard_map`` (full-manual — partial-
    auto does not lower on legacy CPU backends), runs ``fn`` on each
    shard, and merges with the segment-sum trick the aggregation plans
    use: each device scatters its shard into the zeros of the full
    (n_rows,) output at its global row offsets and one ``psum`` across
    the axis adds the disjoint segments back together.

    ``n_rows`` not divisible by the axis size is handled by padding
    with copies of row 0 (computed and discarded — every device keeps
    an identical shard shape, which shard_map requires).
    """
    ndev = mesh.shape[axis]
    pad = (-n_rows) % ndev
    total = n_rows + pad
    shard = total // ndev

    def body(*local):
        vals = fn(*local)                               # (shard,)
        idx = jax.lax.axis_index(axis) * shard + jnp.arange(shard)
        seg = jax.ops.segment_sum(vals, idx, num_segments=total)
        return jax.lax.psum(seg, axis)

    sharded = compat.shard_map(
        body, mesh=mesh,
        in_specs=P(axis), out_specs=P(),
        axis_names={axis}, check_vma=False)

    def run(*arrays):
        if pad:
            arrays = tuple(
                jnp.concatenate(
                    [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])])
                for a in map(jnp.asarray, arrays))
        return sharded(*arrays)[:n_rows]

    return run
