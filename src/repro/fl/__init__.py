from repro.fl.aggregation import (
    AggregationPlan,
    fedavg,
    flat_psum,
    hierarchical_fedavg,
    hierarchical_psum,
)
from repro.fl.distributed import FLTrainStep, choose_fl_hierarchy
from repro.fl.orchestrator import FederatedOrchestrator, FederatedRunResult, RoundRecord

__all__ = [
    "AggregationPlan", "fedavg", "flat_psum", "hierarchical_fedavg",
    "hierarchical_psum", "FLTrainStep", "choose_fl_hierarchy",
    "FederatedOrchestrator", "FederatedRunResult", "RoundRecord",
]
