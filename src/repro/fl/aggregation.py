"""Aggregation: flat FedAvg, host-level hierarchical FedAvg, and the
device-level hierarchical collective schedule (the paper's technique as
it lowers onto a TPU mesh).

Key invariant (property-tested): for any valid placement, hierarchical
FedAvg over the placement tree == flat weighted FedAvg. The placement
changes *where* partial sums happen (hence the delay), never the result.

Device-level mapping (DESIGN.md "hierarchical aggregation -> grouped
collectives"): every level of the tree becomes one
``lax.psum(..., 'data', axis_index_groups=...)`` where each aggregation
cluster is a device group (and every uninvolved device sits in a
singleton group — a free no-op). Contributions are masked to one
representative device per carrier client, so multi-device clients and
group-broadcast semantics compose exactly. On the multi-pod mesh the
root level is a plain ``psum`` over the ``pod`` axis — the hierarchy's
top level aligned with the physical DCN boundary.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hierarchy import Hierarchy, RoundPlan
from repro.utils.trees import tree_weighted_sum


# --------------------------------------------------------------------------
# host-level (orchestrator / emulation / property tests)
# --------------------------------------------------------------------------

def fedavg(updates: Sequence, weights: Sequence[float]):
    """Flat weighted FedAvg: sum_i w_i * update_i (weights sum to 1)."""
    return tree_weighted_sum(list(updates), list(weights))


def hierarchical_fedavg(updates: Sequence, weights: Sequence[float],
                        hierarchy: Hierarchy, placement: Sequence[int]):
    """FedAvg computed along the placement tree, bottom-up.

    Every client's contribution w_i * u_i enters at its position (trainer
    under a leaf aggregator, or aggregator's own update at its level);
    each aggregator sums its buffer; the root's sum is the global model.
    Returns (global_update, partials_per_level) — partials are exposed so
    the emulator can time each cluster.
    """
    h = hierarchy
    placement = np.asarray(placement, np.int64)
    h.validate_placement(placement)
    weighted = [jax.tree.map(lambda x: x * w, u)
                for u, w in zip(updates, weights, strict=True)]
    trainers = h.trainer_assignment(placement)
    # value held at each slot, built bottom-up
    slot_value = [None] * h.dimensions
    for level in range(h.depth - 1, -1, -1):
        for s in range(h.level_starts[level], h.level_starts[level + 1]):
            host = int(placement[s])
            parts = [weighted[host]]
            kids = h.children_slots(s)
            if kids:
                parts.extend(slot_value[k] for k in kids)
            else:
                leaf_idx = s - h.level_starts[h.depth - 1]
                parts.extend(weighted[t] for t in trainers[leaf_idx])
            acc = parts[0]
            for p in parts[1:]:
                acc = jax.tree.map(jnp.add, acc, p)
            slot_value[s] = acc
    return slot_value[0]


class SegmentAggregator:
    """jit'd per-level weighted segment-sum executor over client-stacked
    updates — the batched round engine's aggregation hot path.

    The sequential reference dispatches one jit call (+ block) per
    cluster; this dispatches ONE ``segment_sum`` per level over the whole
    (C, ...) stack, consuming the ``RoundPlan`` index tables as data so
    every round reuses the same compiled executables (plan shapes are
    placement-independent). Math is identical: each segment accumulates
    ``[host, children...]`` in the reference's order.

    ELASTIC: :meth:`retarget` points the aggregator at a new hierarchy
    after a mid-run resize. The jit'd executables are keyed by the
    per-level cluster counts — a population change that keeps the tree
    shape (in-window growth/shrink: same depth/width, only trainer
    counts move) keeps every compiled function (jit's own argument-shape
    cache absorbs the new src/seg lengths), and previously-seen shapes
    (ebb-and-flow oscillating between two trees) are served from a
    per-aggregator cache instead of re-jitting each crossing.
    """

    def __init__(self, hierarchy: Hierarchy):
        self._fn_cache: dict = {}      # n_clusters -> jit'd level fn
        self._fused_fns: dict = {}     # tuple(n_clusters) -> fused fn
        self._weight_fn = jax.jit(self._apply_weights,
                                  static_argnames=())
        self._n_clusters: Optional[list] = None
        self.retarget(hierarchy)

    def retarget(self, hierarchy: Hierarchy) -> bool:
        """Adopt ``hierarchy`` (elastic resize); returns True when the
        compiled level executables actually changed (tree shape moved),
        False when everything was reused."""
        n_clusters = [
            lp.n_clusters
            for lp in hierarchy.round_plan(
                np.arange(hierarchy.dimensions)).levels]
        changed = n_clusters != self._n_clusters
        self.hierarchy = hierarchy
        if changed:
            self._n_clusters = n_clusters
            self._level_fns = [
                self._fn_cache.setdefault(n, self._make_level_fn(n))
                for n in n_clusters]
        return changed

    # ---- the two shared math bodies (every path goes through these) --
    @staticmethod
    def _apply_weights(stacked, w):
        return jax.tree.map(
            lambda x: x * w.reshape((-1,) + (1,) * (x.ndim - 1)
                                    ).astype(x.dtype), stacked)

    @staticmethod
    def _reduce_level(weighted, child_vals, src, seg, n_clusters):
        """One level: gather [clients | child clusters] pools, segment-sum
        per cluster (host-first order, zero-padded tails exact)."""
        def one(x, cv):
            pool = x if cv is None else jnp.concatenate([x, cv], axis=0)
            return jax.ops.segment_sum(
                pool[src], seg, num_segments=n_clusters,
                indices_are_sorted=True)
        if child_vals is None:
            return jax.tree.map(lambda x: one(x, None), weighted)
        return jax.tree.map(one, weighted, child_vals)

    # ------------------------------------------------------------------
    @classmethod
    def _make_level_fn(cls, n_clusters: int):
        return jax.jit(functools.partial(cls._reduce_level,
                                         n_clusters=n_clusters),
                       static_argnames=())

    def weighted(self, stacked_updates, weights):
        """stacked (C, ...) pytree * per-client weights -> weighted stack."""
        return self._weight_fn(stacked_updates,
                               jnp.asarray(weights, jnp.float32))

    def _make_fused(self, n_clusters: tuple):
        def fused(stacked, w, srcs, segs):
            vals = None
            weighted = self._apply_weights(stacked, w)
            for i, (src, seg) in enumerate(zip(srcs, segs, strict=True)):
                vals = self._reduce_level(weighted, vals, src, seg,
                                          n_clusters[i])
            return jax.tree.map(lambda x: x[0], vals)

        return jax.jit(fused, static_argnames=())

    def aggregate_fused(self, stacked_updates, weights, plan: RoundPlan):
        """Weighting + every level + root extraction in ONE jit call —
        the deterministic-timing hot path (no per-level host syncs).
        Fused executables are cached per tree shape, so an elastic run
        oscillating between two hierarchies compiles each once."""
        key = tuple(self._n_clusters)
        fn = self._fused_fns.get(key)
        if fn is None:
            fn = self._fused_fns[key] = self._make_fused(key)
        return fn(stacked_updates, jnp.asarray(weights, jnp.float32),
                  tuple(jnp.asarray(lp.src) for lp in plan.levels),
                  tuple(jnp.asarray(lp.seg) for lp in plan.levels))

    def run_level(self, idx: int, weighted, child_vals, plan: RoundPlan):
        lp = plan.levels[idx]
        return self._level_fns[idx](
            weighted, child_vals, jnp.asarray(lp.src), jnp.asarray(lp.seg))

    def aggregate(self, weighted, plan: RoundPlan):
        """Run all levels bottom-up; returns the root cluster's value."""
        vals = None
        for idx in range(len(plan.levels)):
            vals = self.run_level(idx, weighted, vals, plan)
        return jax.tree.map(lambda x: x[0], vals)


def batched_hierarchical_fedavg(stacked_updates, weights,
                                hierarchy: Hierarchy,
                                placement: Sequence[int]):
    """``hierarchical_fedavg`` over a client-stacked pytree in one pass
    per level (property-tested equal to the sequential reference)."""
    agg = SegmentAggregator(hierarchy)
    plan = hierarchy.round_plan(np.asarray(placement, np.int64))
    return agg.aggregate(agg.weighted(stacked_updates, weights), plan)


# --------------------------------------------------------------------------
# device-level plan
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class AggregationPlan:
    """Static schedule for the in-mesh hierarchical aggregation.

    Built on the host from (hierarchy, placement, #devices); consumed
    inside shard_map. All members are plain numpy so the plan hashes into
    the jit cache via closure capture.
    """
    n_devices: int                       # extent of the data axis (per pod)
    client_of_device: np.ndarray         # (n_devices,) int
    weight_of_device: np.ndarray         # (n_devices,) f32: w_c / n_dev_c
    client_groups: tuple                 # device groups: one per client
    levels: tuple                        # per level, deepest first:
    #   (groups, carrier_mask, in_group_mask)
    root_rep_mask: np.ndarray            # (n_devices,) 0/1: root-group reps

    @staticmethod
    def build(hierarchy: Hierarchy, placement: Sequence[int],
              n_devices: int, weights: Optional[Sequence[float]] = None
              ) -> "AggregationPlan":
        n_clients = hierarchy.total_clients
        if n_devices % n_clients != 0:
            raise ValueError(
                f"data axis ({n_devices}) must be a multiple of the client "
                f"count ({n_clients})")
        per = n_devices // n_clients
        client_of_device = np.repeat(np.arange(n_clients), per)
        if weights is None:
            weights = np.full(n_clients, 1.0 / n_clients)
        weights = np.asarray(weights, np.float32)
        weight_of_device = weights[client_of_device] / per

        def devices_of(c: int) -> List[int]:
            return list(range(c * per, (c + 1) * per))

        def rep_of(c: int) -> int:
            return c * per

        client_groups = tuple(tuple(devices_of(c)) for c in range(n_clients))

        clusters = hierarchy.clusters(placement)  # deepest level first
        levels = []
        for level_clusters in clusters:
            groups: List[tuple] = []
            carrier = np.zeros(n_devices, np.float32)
            in_group = np.zeros(n_devices, np.float32)
            grouped_devices: set = set()
            for members in level_clusters:
                devs: List[int] = []
                for c in members:
                    devs.extend(devices_of(c))
                    carrier[rep_of(c)] = 1.0
                groups.append(tuple(sorted(devs)))
                grouped_devices.update(devs)
                for d in devs:
                    in_group[d] = 1.0
            for d in range(n_devices):
                if d not in grouped_devices:
                    groups.append((d,))
            levels.append((tuple(groups), carrier, in_group))

        root_host = int(placement[0])
        root_rep = np.zeros(n_devices, np.float32)
        root_rep[rep_of(root_host)] = 1.0
        return AggregationPlan(
            n_devices=n_devices,
            client_of_device=client_of_device,
            weight_of_device=weight_of_device.astype(np.float32),
            client_groups=client_groups,
            levels=tuple(levels),
            root_rep_mask=root_rep,
        )


def hierarchical_psum(value, plan: AggregationPlan, axis_name: str = "data",
                      pod_axis: Optional[str] = None):
    """The paper's aggregation tree as grouped collectives.

    Call INSIDE shard_map over (pod_axis?, axis_name). ``value`` is this
    device's (weighted-below) local update leaf or pytree. Returns the
    globally aggregated value, broadcast to every device.
    """
    d = jax.lax.axis_index(axis_name)
    w = jnp.asarray(plan.weight_of_device)[d]
    v = jax.tree.map(lambda x: x * w.astype(x.dtype), value)

    # 1) client-internal reduce: every device of a client holds w_c * u_c
    v = jax.tree.map(
        lambda x: jax.lax.psum(x, axis_name,
                               axis_index_groups=[list(g) for g in
                                                  plan.client_groups]), v)

    # 2) tree levels, deepest first
    for groups, carrier, in_group in plan.levels:
        cm = jnp.asarray(carrier)[d]
        gm = jnp.asarray(in_group)[d]

        def level_reduce(x, cm=cm, gm=gm, groups=groups):
            masked = x * cm.astype(x.dtype)
            summed = jax.lax.psum(
                masked, axis_name,
                axis_index_groups=[list(g) for g in groups])
            return jnp.where(gm.astype(bool), summed, x)

        v = jax.tree.map(level_reduce, v)

    # 3) broadcast the root's total to the whole data axis
    rm = jnp.asarray(plan.root_rep_mask)[d]
    v = jax.tree.map(
        lambda x: jax.lax.psum(x * rm.astype(x.dtype), axis_name), v)

    # 4) multi-pod: the top of the hierarchy crosses the DCN boundary.
    # Per-pod weights each sum to 1, so the global model is the pod mean.
    if pod_axis is not None:
        v = jax.tree.map(lambda x: jax.lax.pmean(x, pod_axis), v)
    return v


def flat_psum(value, plan: AggregationPlan, axis_name: str = "data",
              pod_axis: Optional[str] = None):
    """CFL baseline: one global all-reduce (weighted)."""
    d = jax.lax.axis_index(axis_name)
    w = jnp.asarray(plan.weight_of_device)[d]
    v = jax.tree.map(
        lambda x: jax.lax.psum(x * w.astype(x.dtype), axis_name), value)
    if pod_axis is not None:
        v = jax.tree.map(lambda x: jax.lax.pmean(x, pod_axis), v)
    return v
